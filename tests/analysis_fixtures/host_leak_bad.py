"""Fixture: host syncs, data-dependent Python control flow, and host
robustness state inside the traced closure — must trip
``host-leak-into-trace``."""
import jax
import numpy as np


@jax.jit
def branch_on_traced(x, y):
    # BAD: Python `if` on a traced value — concretization error at best
    if x > 0:
        return y
    return -y


@jax.jit
def sync_item(x):
    # BAD: .item() forces a device->host sync per call
    return x.item()


@jax.jit
def host_roundtrip(x):
    # BAD: float()/np.asarray pull the traced value to host
    s = float(x)
    return np.asarray(x) * s


@jax.jit
def reads_fault_plane(engine, x):
    # BAD: the fault/recovery plane must never leak into compiled code
    if engine.fault_injector is not None:
        return x
    return x + 1.0
