"""Assigned-architecture registry: ``get_config("<id>")`` / ``--arch <id>``."""
from importlib import import_module

ARCH_IDS = (
    "chameleon_34b", "chatglm3_6b", "deepseek_7b", "starcoder2_15b",
    "llama3_2_3b", "recurrentgemma_9b", "dbrx_132b", "qwen3_moe_30b_a3b",
    "xlstm_1_3b", "whisper_base",
)

_ALIASES = {
    "chameleon-34b": "chameleon_34b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-7b": "deepseek_7b",
    "starcoder2-15b": "starcoder2_15b",
    "llama3.2-3b": "llama3_2_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "xlstm-1.3b": "xlstm_1_3b",
    "whisper-base": "whisper_base",
}


def get_config(arch_id: str):
    mod_name = _ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return import_module(f"repro.configs.{mod_name}").CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
