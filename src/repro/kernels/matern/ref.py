"""Pure-jnp oracle for the Matérn-5/2 gram kernel (no Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT5 = 2.2360679774997896


def matern52_gram_ref(x1: jax.Array, x2: jax.Array, inv_lengthscale: jax.Array,
                      amplitude: jax.Array) -> jax.Array:
    """k(x1, x2): (n1, n2).  x*: (n*, D); inv_lengthscale: (D,); amplitude: ()."""
    a = x1 * inv_lengthscale
    b = x2 * inv_lengthscale
    d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
          - 2.0 * (a @ b.T))
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-36)
    return amplitude * (1.0 + SQRT5 * r + (5.0 / 3.0) * d2) * \
        jnp.exp(-SQRT5 * r)
