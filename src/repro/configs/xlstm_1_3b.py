"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks (xLSTM[7:1]), no separate FFN
(d_ff=0).  48L d_model=2048 4H vocab=50304.  [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    slstm_every=8, mlstm_chunk=256, conv_width=4,
    norm="layernorm", activation="gelu",
    sub_quadratic=True,
)
