"""Multi-start acquisition-function optimization — the paper's Algorithm 1/2.

Four strategies behind one API (`maximize_acqf`):

* ``seq``      — SEQ. OPT.: B sequential scipy L-BFGS-B runs (Algorithm 2).
* ``cbe``      — C-BE: one scipy L-BFGS-B over the flattened (B·D,) summed
                 objective (BoTorch ≤0.14 practice; off-diagonal artifacts).
* ``dbe``      — D-BE (paper): coroutine-decoupled scipy workers + batched
                 evaluation, shrinking active set.
* ``dbe_vec``  — D-BE vectorized (ours, beyond-paper): device-resident batched
                 L-BFGS-B (`core.lbfgsb`), one jitted program, zero host syncs.

All strategies *maximize* the acquisition function (internally minimizing its
negation, matching BoTorch/Optuna conventions), and ALL of them route their
evaluations through one :class:`repro.engine.EvalEngine`: the engine owns the
jitted ``(-acq, -∇acq)`` primitive, the shape-bucketed pad-or-shrink
schedule for shrinking active sets, and the q-batch (joint-candidate)
layout.  The strategies differ only in who drives the quasi-Newton updates.

Compilation discipline: the acquisition is passed as a *module-level pure
function* ``acq_fn(state, X) -> (k,)`` plus a pytree ``state`` (GP arrays,
incumbent, ...).  The engine's jit caches key on the function identity and
shapes only, so a 300-trial BO run with size-bucketed GP states compiles
each strategy a handful of times total.

q-batch mode: with ``q > 1`` each restart optimizes a *joint* block of q
candidates (``x0``: (B, q, D); ``acq_fn`` receives (k, q, D)) — the
workload of joint q-EI maximization (Wilson et al. 2018).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, TYPE_CHECKING

if TYPE_CHECKING:                      # engine is imported lazily at runtime
    from repro.engine.engine import EvalEngine

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coroutine as co
from repro.core.lbfgsb import LbfgsbOptions
# NOTE: only the dependency-free repro.engine.plan may be imported here.
# repro.engine.engine is imported lazily inside maximize_acqf: it imports
# core.lbfgsb, whose package __init__ re-enters this module — so when
# repro.engine is imported FIRST, engine.engine is mid-initialization at
# this point and a top-level `from repro.engine.engine import ...` raises
# ImportError (partially initialized module).  Verified both orders.
from repro.engine.plan import EvalPlan

Array = jax.Array

STRATEGIES = ("seq", "cbe", "dbe", "dbe_vec")

# acq_fn(state, X:(k,D)|(k,q,D)) -> (k,) acquisition values (max scale)
AcqStateFn = Callable[[Any, Array], Array]


@dataclass
class MsoOptions:
    m: int = 10                  # L-BFGS-B memory
    maxiter: int = 200           # per-restart iteration cap (paper setting)
    pgtol: float = 1e-2          # paper: ||∇α||_inf ≤ 1e-2
    maxls: int = 25
    ftol: float = 0.0            # disabled by default, like the paper
    bucketed: bool = True        # geometric eval buckets (False: pad-to-B)


@dataclass
class MsoResult:
    x: np.ndarray                # (B, D) / (B, q, D) per-restart maximizers
    acq: np.ndarray              # (B,)  acquisition values (max scale)
    best_x: np.ndarray           # (D,) / (q, D)
    best_acq: float
    n_iters: np.ndarray          # (B,) QN iterations per restart
    n_evals: np.ndarray          # (B,) objective evals per restart
    n_rounds: int                # batched evaluation rounds (wall-clock proxy)
    wall_time: float
    strategy: str
    q: int = 1
    engine_stats: Optional[dict] = None   # EvalEngine.stats_snapshot()


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def mso_result_from_lockstep(res, x0_shape, wall: float, *, q: int = 1,
                             engine_stats: Optional[dict] = None
                             ) -> MsoResult:
    """Materialize a device ``LbfgsbResult`` into an :class:`MsoResult`.

    Shared by the ``dbe_vec`` branch below and the fused ask pipeline
    (``engine/ask.py``) so both report the lockstep solve identically.
    """
    res = jax.tree.map(np.asarray, res)
    acq = -res.f
    best = int(np.argmax(acq))
    xs = res.x.reshape(x0_shape)
    return MsoResult(x=xs, acq=acq, best_x=xs[best],
                     best_acq=float(acq[best]), n_iters=res.k,
                     n_evals=res.n_evals, n_rounds=int(res.rounds),
                     wall_time=wall, strategy="dbe_vec", q=q,
                     engine_stats=engine_stats)


def maximize_acqf(
    acq_fn: AcqStateFn,
    x0: np.ndarray,
    lower: np.ndarray,
    upper: np.ndarray,
    *,
    acq_state: Any = None,
    strategy: str = "dbe",
    options: Optional[MsoOptions] = None,
    q: int = 1,
    engine: Optional["EvalEngine"] = None,   # noqa: F821 (lazy import)
) -> MsoResult:
    """Run MSO with the chosen strategy.

    ``x0``: (B, D) restart points, or (B, q, D) joint blocks when q > 1.
    ``acq_fn(state, X)`` should be a module-level function for jit-cache
    reuse; pass per-trial data (fitted GP, incumbent) through ``acq_state``.
    ``engine``: reuse a long-lived :class:`EvalEngine` (a BO sampler keeps
    one per run); defaults to the process-wide engine for ``acq_fn``.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    options = options if options is not None else MsoOptions()

    x0 = np.asarray(x0, np.float64)
    if q > 1:
        if x0.ndim != 3 or x0.shape[1] != q:
            raise ValueError(f"q={q} needs x0 of shape (B, q, D); "
                             f"got {x0.shape}")
    elif x0.ndim != 2:
        raise ValueError(f"x0 must be (B, D); got {x0.shape}")
    B = x0.shape[0]
    D = x0.shape[-1]

    from repro.engine.engine import default_engine

    plan = EvalPlan.for_batch(B, D, q=q, bucketed=options.bucketed)
    eng = engine if engine is not None else default_engine(acq_fn)

    # flat (B, q·D) view for the QN solvers; bounds tile across the q axis
    x0f = x0.reshape(B, plan.flat_dim)
    lower = np.broadcast_to(np.asarray(lower, np.float64), (D,))
    upper = np.broadcast_to(np.asarray(upper, np.float64), (D,))
    lowf = np.tile(lower, q)
    upf = np.tile(upper, q)

    if strategy == "dbe_vec":
        opts = LbfgsbOptions(m=options.m, maxiter=options.maxiter,
                             pgtol=options.pgtol, ftol=options.ftol,
                             maxls=options.maxls)
        t0 = time.perf_counter()
        res = eng.run_lockstep(
            acq_state, jnp.asarray(x0f),
            jnp.asarray(np.broadcast_to(lowf, x0f.shape)),
            jnp.asarray(np.broadcast_to(upf, x0f.shape)),
            opts, plan)
        wall = time.perf_counter() - t0
        return mso_result_from_lockstep(res, x0.shape, wall, q=q,
                                        engine_stats=eng.stats_snapshot())

    batch_eval = eng.evaluator(acq_state, plan)
    kw = dict(m=options.m, maxiter=options.maxiter, pgtol=options.pgtol,
              maxls=options.maxls, factr=0.0)
    t0 = time.perf_counter()
    if strategy == "seq":
        out = co.run_seq_opt(batch_eval, x0f, lowf, upf, **kw)
    elif strategy == "cbe":
        out = co.run_cbe(batch_eval, x0f, lowf, upf, **kw)
    else:
        out = co.run_dbe_coroutine(batch_eval, x0f, lowf, upf, **kw)
    wall = time.perf_counter() - t0

    acq = -out.f
    best = int(np.argmax(acq))
    xs = out.x.reshape(x0.shape)
    return MsoResult(x=xs, acq=acq, best_x=xs[best],
                     best_acq=float(acq[best]), n_iters=out.n_iters,
                     n_evals=out.n_evals, n_rounds=out.n_rounds,
                     wall_time=wall, strategy=strategy, q=q,
                     engine_stats=eng.stats_snapshot())


def closure_engine(acq_batched):
    """Build a reusable :class:`~repro.engine.EvalEngine` for a plain
    closure ``X -> (k,)`` — THE way to amortize compiles across
    :func:`maximize_acqf_closure` calls (the engine is tagged with its
    source closure so the wrapper can verify consistency)."""
    from repro.engine.engine import EvalEngine

    def fn(state, X):
        del state
        return acq_batched(X)
    fn.__wrapped_closure__ = acq_batched
    return EvalEngine(fn)


def maximize_acqf_closure(acq_batched, x0, lower, upper, *,
                          strategy="dbe", options=None, q=1, engine=None):
    """Convenience wrapper for plain closures ``X -> (k,)`` (tests/examples).

    Recompile behavior: the engine's jit caches key on *function
    identity*, and every call here wraps ``acq_batched`` in a fresh
    state-form function — so calling this in a loop with fresh closures
    retraces per call (fine outside hot loops).  To reuse compiled
    programs across calls, pass ``engine=closure_engine(acq_batched)``
    built once, or use :func:`maximize_acqf` directly with a
    module-level ``acq_fn(state, X)`` and per-call ``acq_state``.

    An ``engine`` evaluates ITS OWN captured ``acq_fn`` — so one built
    from a different closure would silently maximize the wrong
    acquisition; this wrapper rejects any engine not built from
    ``acq_batched`` (via :func:`closure_engine`'s tag).
    """
    if engine is not None:
        src = getattr(engine.acq_fn, "__wrapped_closure__", None)
        if src is not acq_batched and engine.acq_fn is not acq_batched:
            raise ValueError(
                "engine= was built from a different closure than "
                "acq_batched (the engine evaluates its own acq_fn); "
                "build it with closure_engine(acq_batched)")

    def fn(state, X):
        del state
        return acq_batched(X)
    return maximize_acqf(fn, x0, lower, upper, acq_state=None,
                         strategy=strategy, options=options, q=q,
                         engine=engine)
