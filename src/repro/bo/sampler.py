"""GPSampler-style Bayesian-optimization controller (ask/tell).

This is the Optuna-integration analogue the paper ships: each `ask` fits a
Matérn-5/2 GP on the observations, builds LogEI, and runs multi-start
L-BFGS-B with a pluggable MSO strategy (`seq` / `cbe` / `dbe` / `dbe_vec`).

Fault tolerance at the controller level: every suggestion is journaled
before being handed out; `tell` completes it; a crashed/preempted trial is
simply re-suggested on resume (`GPSampler.load`).  The controller is the BO
"control plane" driving the distributed trainer in `examples/hpo_train.py`.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bo.space import BoxSpace
from repro.core.acquisition import logei_acq
from repro.core.mso import MsoOptions, MsoResult, maximize_acqf
from repro.engine import EvalEngine, fused_logei_acq, resolve_backend
from repro.gp.fit import fit_gp, standardize
from repro.gp.gpr import with_kinv


@dataclass
class Trial:
    trial_id: int
    x: np.ndarray
    y: Optional[float] = None
    state: str = "pending"           # pending | complete | failed
    ask_time: float = 0.0
    tell_time: float = 0.0


@dataclass
class SamplerStats:
    n_gp_fits: int = 0
    fit_time: float = 0.0
    acqf_time: float = 0.0
    acqf_iters: List[float] = field(default_factory=list)
    acqf_rounds: List[int] = field(default_factory=list)
    engine: Optional[dict] = None       # last EvalEngine.stats_snapshot()


class GPSampler:
    """Ask/tell BO over a box space; strategy selects the MSO scheme."""

    def __init__(
        self,
        space: BoxSpace,
        *,
        strategy: str = "dbe",
        n_startup_trials: int = 10,
        n_restarts: int = 10,
        mso_options: Optional[MsoOptions] = None,
        seed: int = 0,
        pad_multiple: int = 32,
        gp_fit_restarts: int = 2,
        posterior_backend: str = "auto",
    ):
        self.space = space
        self.strategy = strategy
        self.n_startup = n_startup_trials
        self.B = n_restarts
        # fresh per instance: a shared default dataclass would leak option
        # mutations across samplers
        self.mso_options = (mso_options if mso_options is not None
                            else MsoOptions())
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.pad_multiple = pad_multiple
        self.gp_fit_restarts = gp_fit_restarts
        self.posterior_backend = resolve_backend(posterior_backend)
        # ONE evaluation engine for the whole BO run: every trial's MSO
        # (any strategy) reuses its shape-bucketed jit caches, so compile
        # counts stay O(log B · #GP-size-buckets), not O(trials)
        self._acq_fn = (logei_acq if self.posterior_backend == "xla"
                        else fused_logei_acq(self.posterior_backend))
        self.engine = EvalEngine(self._acq_fn)
        self.trials: List[Trial] = []
        self.stats = SamplerStats()
        self.last_mso: Optional[MsoResult] = None

    # ----------------------------------------------------------------- api
    def ask(self) -> Trial:
        n_done = sum(t.state == "complete" for t in self.trials)
        if n_done < self.n_startup:
            x = self.space.sample(self.rng, 1)[0]
        else:
            x = self._suggest()
        t = Trial(trial_id=len(self.trials), x=x, ask_time=time.time())
        self.trials.append(t)
        return t

    def tell(self, trial_id: int, y: float, *, failed: bool = False):
        t = self.trials[trial_id]
        t.y = None if failed else float(y)
        t.state = "failed" if failed else "complete"
        t.tell_time = time.time()

    def best(self) -> Trial:
        done = [t for t in self.trials if t.state == "complete"]
        return min(done, key=lambda t: t.y)

    def optimize(self, objective, n_trials: int):
        for _ in range(n_trials):
            t = self.ask()
            try:
                self.tell(t.trial_id, objective(t.x))
            except Exception:
                self.tell(t.trial_id, 0.0, failed=True)
        return self.best()

    # -------------------------------------------------------- inner engine
    def _observations(self):
        done = [t for t in self.trials if t.state == "complete"]
        X = np.stack([t.x for t in done])
        y = np.array([t.y for t in done])
        return X, y

    def _suggest(self) -> np.ndarray:
        X, y = self._observations()
        U = self.space.to_unit(X)
        # minimize y == maximize -y (standardized)
        t0 = time.perf_counter()
        y_std, _, _ = standardize(jnp.asarray(-y))
        gp = fit_gp(jnp.asarray(U), y_std, n_restarts=self.gp_fit_restarts,
                    seed=self.seed + len(self.trials),
                    pad_bucket=self.pad_multiple)
        if self.posterior_backend != "xla":
            gp = with_kinv(gp)      # fused quadratic-form posterior input
        self.stats.n_gp_fits += 1
        self.stats.fit_time += time.perf_counter() - t0

        best_val = jnp.max(y_std)

        # restart points: incumbent + (B-1) uniform (GPSampler-style)
        inc = U[int(np.argmin(y))]
        rand = self.rng.uniform(0.0, 1.0, (self.B - 1, self.space.dim))
        x0 = np.concatenate([inc[None], rand], 0)

        t0 = time.perf_counter()
        res = maximize_acqf(self._acq_fn, x0, 0.0, 1.0,
                            acq_state=(gp, best_val),
                            strategy=self.strategy,
                            options=self.mso_options,
                            engine=self.engine)
        self.stats.acqf_time += time.perf_counter() - t0
        self.stats.acqf_iters.append(float(np.median(res.n_iters)))
        self.stats.acqf_rounds.append(res.n_rounds)
        self.stats.engine = res.engine_stats
        self.last_mso = res
        return self.space.from_unit(np.clip(res.best_x, 0.0, 1.0))

    # ------------------------------------------------- journal (restart)
    def save(self, path: str):
        rec = {
            "seed": self.seed,
            "strategy": self.strategy,
            "lower": self.space.lower.tolist(),
            "upper": self.space.upper.tolist(),
            "trials": [
                dict(trial_id=t.trial_id, x=t.x.tolist(), y=t.y,
                     state=t.state) for t in self.trials
            ],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, path)        # atomic

    @classmethod
    def load(cls, path: str, **kwargs) -> "GPSampler":
        with open(path) as f:
            rec = json.load(f)
        space = BoxSpace(np.array(rec["lower"]), np.array(rec["upper"]))
        s = cls(space, strategy=rec["strategy"], seed=rec["seed"], **kwargs)
        for tr in rec["trials"]:
            t = Trial(trial_id=tr["trial_id"], x=np.array(tr["x"]),
                      y=tr["y"], state=tr["state"])
            if t.state == "pending":
                # a trial that never came back (crash/preemption):
                # mark failed; its parameters will be re-explored naturally.
                t.state = "failed"
            s.trials.append(t)
        return s
