"""Training/serving runtime tests: optimizer, compression, checkpoints,
continuous-batching engine, BO sampler journal."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.ckpt.manager import CheckpointManager
from repro.data.synth import DataConfig, synth_batch
from repro.models import lm
from repro.serve.engine import Request, ServeEngine
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama3_2_3b").reduced().replace(dtype="float32",
                                                      attn_chunk=16)
    params = lm.init_params(KEY, cfg)
    return cfg, params


def _fixed_batch(cfg, B=8, S=32):
    d = DataConfig(global_batch=B, seq_len=S, seed=0)
    return {k: jnp.asarray(v) for k, v in synth_batch(cfg, d, 0).items()}


def test_adamw_overfits_fixed_batch(tiny):
    cfg, params = tiny
    opt_cfg = OptimConfig(lr=2e-3, warmup_steps=2, total_steps=100)
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2))
    batch = _fixed_batch(cfg)
    first = last = None
    for i in range(25):
        params, opt_state, m = step(params, opt_state, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_int8_ef_compression_converges(tiny):
    cfg, params = tiny
    opt_cfg = OptimConfig(lr=2e-3, grad_compression="int8_ef")
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _fixed_batch(cfg)
    first = last = None
    for i in range(25):
        params, opt_state, m = step(params, opt_state, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_grad_accum_equivalence(tiny):
    """grad_accum=k equals one big batch (mean-of-means, same data)."""
    cfg, params = tiny
    from repro.train.step import compute_grads
    batch = _fixed_batch(cfg, B=8)
    l1, g1 = jax.jit(lambda p, b: compute_grads(p, cfg, b))(params, batch)
    l2, g2 = jax.jit(lambda p, b: compute_grads(p, cfg, b,
                                                grad_accum=4))(params,
                                                               batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


def test_checkpoint_roundtrip_and_gc(tiny):
    cfg, params = tiny
    opt_cfg = OptimConfig()
    opt_state = init_opt_state(params, opt_cfg)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = {"params": params, "opt": opt_state, "step": jnp.asarray(3)}
        for s in (3, 4, 5):
            mgr.save(s, state, block=True)
        assert mgr.all_steps() == [4, 5]
        restored = mgr.restore(5, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tiny):
    """A tmp file from a dead writer never shadows a real checkpoint."""
    cfg, params = tiny
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        open(os.path.join(d, ".tmp_9_12345"), "w").write("garbage")
        assert mgr.latest_step() is None
        mgr.save(1, {"x": jnp.ones(3)}, block=True)
        assert mgr.latest_step() == 1


def test_engine_continuous_batching(tiny):
    cfg, params = tiny
    eng = ServeEngine(params, cfg, slots=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab_size, 4 + (i % 3)).astype(np.int32),
        max_new_tokens=5) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 5 for r in done)


def test_engine_stats_readable_before_first_step(tiny):
    """stats["compiles"] must exist from construction (reading stats
    before the first step used to KeyError)."""
    cfg, params = tiny
    eng = ServeEngine(params, cfg, slots=2, max_len=64)
    assert eng.stats["compiles"] == 0
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_until_drained()
    assert eng.stats["compiles"] == 1          # one trace, steady state


def test_engine_staggered_admission_prefill(tiny):
    """A request admitted mid-run prefills from its own per-slot offset
    (established slots ride along masked) and must decode exactly what a
    solo run produces."""
    cfg, params = tiny
    rng = np.random.default_rng(2)
    pa = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)

    solo = {}
    for uid, prompt in ((0, pa), (1, pb)):
        e = ServeEngine(params, cfg, slots=2, max_len=64)
        e.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
        solo[uid] = e.run_until_drained()[0].out_tokens

    eng = ServeEngine(params, cfg, slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=pa, max_new_tokens=6))
    for _ in range(3):                  # A decodes alone for a few steps
        eng.step()
    eng.submit(Request(uid=1, prompt=pb, max_new_tokens=6))
    done = eng.run_until_drained()
    out = {r.uid: r.out_tokens for r in done}
    assert out[0] == solo[0], (out[0], solo[0])
    assert out[1] == solo[1], (out[1], solo[1])

    # chunked prefill: B joins A's in-flight prefill wave at its own
    # offset 0 while A resumes from its cursor — never restarting at
    # token 0 — and both still decode the solo outputs
    eng2 = ServeEngine(params, cfg, slots=2, max_len=64, prefill_chunk=2)
    eng2.submit(Request(uid=0, prompt=pa, max_new_tokens=6))
    eng2.step()                         # A prefills 2 of 8 prompt steps
    assert eng2._prefilling == {0} and eng2.positions[0] == 2
    eng2.submit(Request(uid=1, prompt=pb, max_new_tokens=6))
    done = eng2.run_until_drained()
    out = {r.uid: r.out_tokens for r in done}
    assert out[0] == solo[0], (out[0], solo[0])
    assert out[1] == solo[1], (out[1], solo[1])


def test_engine_slot_isolation(tiny):
    """A request's outputs must not depend on what previously occupied its
    slot (cache reset on admission)."""
    cfg, params = tiny
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    eng1 = ServeEngine(params, cfg, slots=1, max_len=64)
    eng1.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    ref = eng1.run_until_drained()[0].out_tokens

    eng2 = ServeEngine(params, cfg, slots=1, max_len=64)
    eng2.submit(Request(uid=0, prompt=rng.integers(
        0, cfg.vocab_size, 9).astype(np.int32), max_new_tokens=4))
    eng2.submit(Request(uid=1, prompt=prompt, max_new_tokens=4))
    out = eng2.run_until_drained()
    second = [r for r in out if r.uid == 1][0].out_tokens
    assert second == ref, (second, ref)


def test_sampler_journal_resume():
    from repro.bo.sampler import GPSampler
    from repro.bo.space import BoxSpace
    space = BoxSpace.cube(3, -1.0, 1.0)
    s = GPSampler(space, strategy="dbe_vec", seed=0, n_startup_trials=4)

    def obj(x):
        return float(np.sum(x ** 2))

    for _ in range(5):
        t = s.ask()
        s.tell(t.trial_id, obj(t.x))
    pending = s.ask()                       # crash before tell
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "journal.json")
        s.save(path)
        s2 = GPSampler.load(path)
        assert len(s2.trials) == 6
        assert s2.trials[pending.trial_id].state == "failed"
        t = s2.ask()                        # resumes cleanly
        s2.tell(t.trial_id, obj(t.x))
        assert s2.best().y <= s.best().y + 1e-12


def test_bo_beats_random_search():
    from repro.bo.sampler import GPSampler
    from repro.bo.space import BoxSpace
    rng = np.random.default_rng(0)
    space = BoxSpace.cube(3, -2.0, 2.0)

    def obj(x):
        return float(np.sum((x - 0.7) ** 2))

    s = GPSampler(space, strategy="dbe_vec", seed=0, n_startup_trials=6)
    best_bo = s.optimize(obj, 22).y
    xs = space.sample(rng, 22)
    best_rand = min(obj(x) for x in xs)
    assert best_bo < best_rand, (best_bo, best_rand)
