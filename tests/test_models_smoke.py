"""Per-architecture smoke tests (the deliverable-(f) contract): reduced
same-family config, one forward/train step on CPU, shape + finiteness
assertions; plus decode↔forward consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import layers as L
from repro.models import lm
from repro.models import whisper as wh

KEY = jax.random.PRNGKey(0)


def _reduced(arch):
    return get_config(arch).reduced().replace(dtype="float32",
                                              attn_chunk=16)


def _batch(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    tgts = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    b = {"tokens": toks, "targets": tgts}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model),
                                        jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = _reduced(arch)
    batch = _batch(cfg)
    if cfg.family == "encdec":
        params = wh.init_params(KEY, cfg)
        loss = wh.lm_loss(params, cfg, batch)
    else:
        params = lm.init_params(KEY, cfg)
        hidden, aux = lm.forward(params, cfg, batch["tokens"])
        assert hidden.shape == (2, 32, cfg.d_model)
        assert np.isfinite(float(aux))
        loss = lm.lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert 2.0 < float(loss) < 12.0          # ~ln(vocab) at random init


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_step(arch):
    """One gradient step: finite grads for every parameter leaf."""
    from repro.train.optim import OptimConfig, init_opt_state
    from repro.train.step import make_train_step
    cfg = _reduced(arch)
    if cfg.is_moe:
        cfg = cfg.replace(moe_capacity_factor=2.0)
    batch = _batch(cfg)
    init = wh.init_params if cfg.family == "encdec" else lm.init_params
    params = init(KEY, cfg)
    opt_cfg = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg)
    new_params, new_state, metrics = jax.jit(step)(params, opt_state,
                                                   batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["llama3_2_3b", "recurrentgemma_9b",
                                  "xlstm_1_3b", "chameleon_34b",
                                  "chatglm3_6b", "whisper_base"])
def test_decode_matches_forward(arch):
    """Step-by-step decode logits == teacher-forced logits (caches,
    ring windows, RG-LRU carry, chunkwise mLSTM state passing)."""
    cfg = get_config(arch).reduced().replace(
        dtype="float32", attn_chunk=8, mlstm_chunk=4, remat="none")
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    if cfg.family == "encdec":
        params = wh.init_params(KEY, cfg)
        enc = wh.encode(params, cfg, jax.random.normal(
            KEY, (B, 8, cfg.d_model), jnp.float32))
        hid = wh.decode_train(params, cfg, enc, toks)
        ref = L.lm_logits(params["embed"], cfg, hid)
        cache = wh.init_cache(params, cfg, enc, B, S)
        step = lambda t, c, i: wh.decode_step(params, cfg, t, c, i)
    else:
        params = lm.init_params(KEY, cfg)
        hid, _ = lm.forward(params, cfg, toks)
        ref = L.lm_logits(params["embed"], cfg, hid)
        cache = lm.init_cache(cfg, B, S)
        step = lambda t, c, i: lm.decode_step(params, cfg, t, c, i)
    outs = []
    for i in range(S):
        lg, cache = step(toks[:, i:i + 1], cache, jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(ref - dec)) / jnp.max(jnp.abs(ref)))
    assert rel < 2e-3, rel


def test_moe_decode_matches_forward_nodrop():
    """Capacity semantics aside (cf→∞ disables drops), MoE dispatch is
    per-token exact."""
    cfg = get_config("dbrx_132b").reduced().replace(
        dtype="float32", attn_chunk=8, remat="none",
        moe_capacity_factor=100.0)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    params = lm.init_params(KEY, cfg)
    hid, _ = lm.forward(params, cfg, toks)
    ref = L.lm_logits(params["embed"], cfg, hid)
    cache = lm.init_cache(cfg, B, S)
    outs = []
    for i in range(S):
        lg, cache = lm.decode_step(params, cfg, toks[:, i:i + 1], cache,
                                   jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(ref - dec)) / jnp.max(jnp.abs(ref)))
    assert rel < 2e-3, rel


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor some gate mass is dropped (GShard
    semantics) but outputs stay finite."""
    from repro.models.moe import apply_moe, init_moe
    cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
        dtype="float32", moe_capacity_factor=0.5)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(aux) > 0


def test_local_window_attention_masks_far_tokens():
    """RecurrentGemma-style window: queries cannot see beyond the window."""
    from repro.models.layers import attention_xla
    B, S, H, hd = 1, 32, 2, 8
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, H, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = attention_xla(q, k, v, causal=True, window=8, q_pos=pos,
                         kv_pos=pos, chunk=0)
    # perturb a key far outside every query's window: output unchanged
    k2_ = k.at[:, 0].set(100.0)
    out2 = attention_xla(q, k2_, v, causal=True, window=8, q_pos=pos,
                         kv_pos=pos, chunk=0)
    np.testing.assert_allclose(np.asarray(full[:, 9:]),
                               np.asarray(out2[:, 9:]), atol=1e-6)


def test_rope_relative_shift_invariance():
    """RoPE: scores depend only on relative positions."""
    from repro.models.layers import rope
    x = jax.random.normal(KEY, (1, 8, 2, 16), jnp.float32)
    p0 = jnp.arange(8)[None, :]
    p1 = p0 + 17
    r0 = rope(x, p0, 10000.0, 1.0)
    r1 = rope(x, p1, 10000.0, 1.0)
    s0 = jnp.einsum("bqhd,bkhd->bhqk", r0, r0)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", r1, r1)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-4)
