"""The fleet ask plane — vmapped multi-study suggest with slot-based
continuous batching.

PR 2 fused one study's whole suggest path into one device program per GP
size bucket (``engine/ask.py``); at BO sizes (B≈10 restarts, D≈8) that
program still leaves the device almost idle.  This module applies the
paper's D-BE argument once more, *across studies*: stack S whole studies
along a new leading axis — exactly as ``dbe_vec`` stacked restarts — and
serve every study's ``suggest()`` from ONE compiled program per
(GP size bucket, slot count):

* **stacked study state** — per-slot padded ``X (S, b, D)`` / ``y (S,
  b)`` buffers with per-slot observation counts, θ ``(S, P)``, Cholesky
  factors ``(S, b, b)`` and (fused posterior backends) K⁻¹ stacks;
* **vmapped GP cores** — ``refit_core`` / ``incr_core`` (the study-axis
  halves of the PR-2 ask pipeline) run under ``jax.vmap`` with
  heterogeneous per-study ``n`` masks;
* **one lockstep solve for the whole fleet** — restart sampling per slot
  (per-study PRNG streams) feeds a single ``(S, B, D)`` L-BFGS-B solve:
  ``core.lbfgsb`` takes the leading batch shape natively, so QN
  iterations and line-search rounds are shared across the fleet instead
  of vmapping S separate ``while_loop``s;
* **slot-based continuous batching** — mirroring ``serve/engine.py``:
  fixed slot blocks grouped by ``pad_bucket_for`` bucket, queued studies
  admitted at trial boundaries, studies migrating blocks on bucket
  growth (host-side state compaction, θ carried for warm starts), idle
  slots frozen behind benign masked rows.  Blocks of the same (bucket,
  slots) shape share compiled programs, so compile counts stay
  O(#buckets) — independent of how many studies the fleet serves.

Exactness mirrors PR 2: per-slot rows are updated element-wise along the
study axis and the lockstep solver freezes converged/idle rows, so a
study's trajectory is bit-for-bit independent of its slot and of which
other studies share the batch (tests/test_fleet.py).

**Mesh sharding** — pass ``mesh=`` (a 1-D ``"study"`` mesh from
``launch.mesh.make_fleet_mesh``) and every slot block widens to
``cfg.slots × ndev`` rows placed behind ``NamedSharding(mesh,
P("study"))``: device d owns the ``cfg.slots`` contiguous slots
``[d·slots, (d+1)·slots)`` and the three block programs run under
``shard_map``, so each device refits and solves only its own slots.  The
hot loop needs NO cross-device collectives: every stacked op is already
element-wise along the study axis, and each device's lockstep
``while_loop`` runs until its own rows converge.  Pinning the *local*
width to ``cfg.slots`` on every mesh size is what makes trajectories
bit-for-bit placement-independent: a vmap's width changes last-ulp
lowering, but each device always traces the identical fixed-width local
program, and a study's position inside that program is covered by PR 3's
bitwise slot/batch-composition-independence invariant.  The host-side
scheduler balances admissions across per-device occupancy and routes
bucket-growth migrations through the same evict → host-compact →
re-admit path, which now doubles as the cross-device state move; compile
counts stay O(#buckets), independent of S *and* of the mesh's device
count (the programs key on the mesh and the (bucket, slots) shape, never
on per-device occupancy).
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core.lbfgsb import LbfgsbOptions, lbfgsb_minimize
from repro.distributed.sharding import fleet_pspec, fleet_sharding
from repro.engine.ask import (_MSO_DEFAULT, SuggestInfo, incr_core,
                              refit_core, restart_points)
from repro.engine.cache import CountingJit, retrace_report
from repro.engine.engine import EvalEngine
from repro.engine.plan import EvalPlan
from repro.obs import trace as obs
from repro.gp.fit import (FIT_OPTS, _FAR, pad_bucket_for, standardize_masked,
                          theta_bounds, theta_init_grid, unpack_theta)
from repro.gp.gpr import GPState

Array = jax.Array


class FleetFullError(RuntimeError):
    """Admission rejected: the fleet is at its configured capacity
    (``max_studies`` / ``max_queue``).  Callers either surface the
    rejection or degrade to the solo :class:`~repro.engine.ask.AskEngine`
    path (see ``FleetSampler(degrade=...)``)."""


class FleetStudyError(RuntimeError):
    """A study left the fleet (load-shed past its admission deadline, or
    parked after exhausting quarantine retries).  Sync callers get it
    raised; async callers receive the instance through the result
    mailbox (``pop_result``) in place of a suggestion."""


@dataclass(frozen=True)
class FleetConfig:
    """Static description of one fleet ask plane (everything here is baked
    into the compiled programs; a fleet serves studies that share it)."""
    dim: int
    n_restarts: int = 10             # B: incumbent + (B-1) uniform
    slots: int = 8                   # compiled slot-batch width PER DEVICE
    kernel: str = "matern52"
    backend: str = "xla"             # resolved posterior backend
    pad_bucket: int = 32             # GP size-bucket quantum
    refit_interval: int = 8          # full MAP refit cadence (≥1)
    warm_start: bool = True          # seed MAP fits from the slot's prev θ
    gp_fit_restarts: int = 2
    gp_fit_maxiter: int = 60
    mso: LbfgsbOptions = _MSO_DEFAULT
    # robustness knobs — all host-side scheduling/retry policy; none is
    # baked into a compiled program, so changing them never retraces
    max_studies: Optional[int] = None    # live-study cap (admission gate)
    max_queue: Optional[int] = None      # registration-queue cap
    max_blocks: Optional[int] = None     # slot-block cap (device memory)
    admission_timeout: Optional[float] = None   # seconds queued → shed
    quarantine_retries: int = 2          # bad-refit retries before parking
    # bounded exponential backoff between quarantine retries (0 disables:
    # immediate re-runs).  Jitter decorrelates a block's retry storms
    # from its neighbors'; the draw comes from a dedicated host RNG so it
    # is deterministic per engine and never touches study PRNG streams.
    retry_backoff_base: float = 0.0      # seconds before retry attempt 1
    retry_backoff_cap: float = 2.0       # backoff ceiling (seconds)
    retry_backoff_jitter: float = 0.25   # multiplicative jitter fraction

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        if self.refit_interval < 1:
            raise ValueError("refit_interval must be >= 1")
        if self.n_restarts < 2:
            raise ValueError("n_restarts must be >= 2")
        if self.quarantine_retries < 0:
            raise ValueError("quarantine_retries must be >= 0")
        if self.retry_backoff_base < 0.0:
            raise ValueError("retry_backoff_base must be >= 0")


class _Study:
    """Host-side record of one study: observations (source of truth for
    admission/migration compaction), slot assignment, refit bookkeeping,
    and the pending-request/result mailbox."""

    __slots__ = ("sid", "xs", "ys", "tags", "block", "slot", "n_fit",
                 "since_refit", "has_factor", "has_theta", "theta_host",
                 "trial", "pending", "result", "from_device", "deadline",
                 "shed", "parked")

    def __init__(self, sid: Hashable):
        self.sid = sid
        self.xs: List[np.ndarray] = []
        self.ys: List[float] = []
        self.tags: List[Optional[Hashable]] = []   # caller trial ids
        self.block: Optional["_Block"] = None
        self.slot = -1
        self.from_device: Optional[int] = None   # device before migration
        self.n_fit = 0
        self.since_refit = 0
        self.has_factor = False          # factor rows valid (incr eligible)
        self.has_theta = False           # θ row fitted (warm-start eligible)
        self.theta_host: Optional[np.ndarray] = None   # carried on migration
        self.trial = 0                   # suggest counter (default PRNG)
        self.pending: Optional[Tuple[Array, int]] = None  # (key, fit_seed)
        self.result = None  # (x, SuggestInfo) | FleetStudyError | None
        self.deadline: Optional[float] = None    # admission deadline (mono)
        self.shed: Optional[str] = None          # load-shed reason
        self.parked: Optional[str] = None        # quarantine-parked reason

    @property
    def n(self) -> int:
        return len(self.ys)


# Idle slots carry this many benign pseudo-observations: the _FAR pattern
# gives a ~diagonal gram, zero standardized targets, and a fast-converging
# frozen row — never NaNs that would stall the shared lockstep loops.
_IDLE_N = 2


class _Block:
    """One slot block: ``width`` studies padded to one GP size bucket.

    ``width`` is ``cfg.slots`` per mesh device (``cfg.slots`` exactly when
    unsharded): the slot axis splits evenly over the mesh, so every device
    runs the SAME local program on exactly ``cfg.slots`` rows no matter
    how many devices the mesh has — which is what makes trajectories
    bit-for-bit placement-independent (a vmap's width changes last-ulp
    lowering; a slot's position inside a fixed-width vmap never does).
    Blocks with equal (bucket, width) share the fleet's compiled programs
    (the CountingJit caches key on shapes), so adding blocks never adds
    traces.
    """

    def __init__(self, cfg: FleetConfig, bucket: int, dtype,
                 sharding=None, width: Optional[int] = None):
        S, b, D = width or cfg.slots, bucket, cfg.dim
        self.bucket = bucket
        self.sharding = sharding         # NamedSharding(mesh, P(study))
        idle = np.full((b, D), _FAR) + np.arange(b)[:, None]
        self.idle_x = np.asarray(idle)               # host row template
        self.x = self._pin(jnp.asarray(np.tile(idle[None], (S, 1, 1)),
                                       dtype))
        self.y = self._pin(jnp.zeros((S, b), dtype))
        th0 = np.zeros((D + 2,))
        th0[-1] = -4.0                               # theta_init_grid base
        self.theta0 = np.asarray(th0)
        self.theta = self._pin(jnp.asarray(np.tile(th0[None], (S, 1)),
                                           dtype))
        eye = np.eye(b)
        self.chol = self._pin(jnp.asarray(np.tile(eye[None], (S, 1, 1)),
                                          dtype))
        self.alpha = self._pin(jnp.zeros((S, b), dtype))
        self.kinv = (None if cfg.backend == "xla" else
                     self._pin(jnp.asarray(np.tile(eye[None], (S, 1, 1)),
                                           dtype)))
        self.studies: List[Optional[_Study]] = [None] * S

    def _pin(self, a: Array) -> Array:
        """Keep block state on its mesh placement: host-side compaction
        updates (.at[].set scatters) must never silently gather a block
        onto one device."""
        return a if self.sharding is None else jax.device_put(
            a, self.sharding)

    def free_slot(self) -> int:
        for s, st in enumerate(self.studies):
            if st is None:
                return s
        return -1

    def n_valid(self) -> np.ndarray:
        nv = np.full((len(self.studies),), _IDLE_N, np.int32)
        for s, st in enumerate(self.studies):
            if st is not None:
                nv[s] = st.n
        return nv


class FleetEngine:
    """Serve S concurrent studies' ask() from one device program.

    Usage is a request/step/result cycle (continuous batching, mirroring
    ``serve.ServeEngine``): ``observe()`` appends per-study observations,
    ``request_suggest()`` enqueues a study's next ask, ``step()`` admits
    queued studies and runs one fused fleet program per active block, and
    ``pop_result()`` collects each study's suggestion.  ``suggest()``
    wraps the cycle for synchronous (solo) callers — any other studies'
    pending requests ride along in the same step.

    ``mesh`` (optional): a 1-D study mesh (``make_fleet_mesh``).  Slot
    blocks then span ``cfg.slots`` slots on EVERY mesh device
    (``slots × ndev`` total), ``NamedSharding``-split along the slot
    axis, and the three block programs run under ``shard_map`` — each
    device serves only its own fixed-width shard with no collectives in
    the hot loop.  Trajectories are bit-for-bit identical across mesh
    sizes (and to the unsharded fleet); the scheduler balances admissions
    over per-device occupancy and bucket-growth migration becomes a
    cross-device state move when the target slot lives on another device.
    """

    def __init__(self, engine: EvalEngine, cfg: FleetConfig,
                 mesh: Optional[Mesh] = None, journal=None,
                 fault_injector=None, sleep_fn=None):
        self.engine = engine
        self.cfg = cfg
        self.mesh = mesh
        # backoff/latency sleeps go through this hook so tests (and the
        # BO service's virtual-clock mode) can charge simulated time
        # instead of wall-clocking; deterministic jitter from a host RNG
        self._sleep = time.sleep if sleep_fn is None else sleep_fn
        self._backoff_rng = np.random.default_rng(0xB0)
        # durability + chaos hooks (both host-side, both optional):
        # ``journal`` duck-types StudyJournal.append (admission, migration,
        # refit-θ, quarantine, shed records — the sampler journals
        # asks/tells); ``fault_injector`` may override the incremental ok
        # flags / full-refit health flags to force the fallback and
        # quarantine paths deterministically (tests/faults.py)
        self.journal = journal
        self.fault_injector = fault_injector
        # notified as (sid, trial_tag, reason) when an observation is
        # quarantined — FleetSampler marks the owning Trial
        self.on_quarantine: Optional[Callable] = None
        self._plan = EvalPlan.for_batch(cfg.n_restarts, cfg.dim)
        self._fit_opts = FIT_OPTS._replace(maxiter=cfg.gp_fit_maxiter)
        if mesh is None:
            self._ndev = 1
            self._slot_sharding = None
            full_impl, incr_impl, mso_impl = (
                self._full_impl, self._incr_impl, self._mso_impl)
            jit_kw: dict = {}
        else:
            if len(mesh.axis_names) != 1:
                raise ValueError("fleet mesh must be 1-D (the study axis);"
                                 f" got axes {mesh.axis_names}")
            self._ndev = int(mesh.devices.size)
            self._slot_sharding = fleet_sharding(mesh)
            # one shard_map per block program: every operand/result leads
            # with the slot axis, so a single P(study) prefix spec splits
            # them all; each device runs the identical slot-local program
            # (check_rep off: nothing is replicated, nothing is reduced)
            spec = fleet_pspec(1, mesh.axis_names[0])

            def smap(fn):
                return shard_map(fn, mesh=mesh, in_specs=spec,
                                 out_specs=spec, check_rep=False)

            full_impl, incr_impl, mso_impl = (
                smap(self._full_impl), smap(self._incr_impl),
                smap(self._mso_impl))
            # key the jit caches on (mesh, spec): host-built per-step
            # operands (keys, masks, θ inits) land on the mesh here, so
            # cache identity never depends on live-device occupancy
            jit_kw = {"in_shardings": self._slot_sharding}
        # three programs per (bucket, slots) shape: full refit,
        # incremental refit, and the fleet MSO tail
        self._full_jit = CountingJit(full_impl, **jit_kw)
        self._incr_jit = CountingJit(incr_impl, **jit_kw)
        self._mso_jit = CountingJit(mso_impl, **jit_kw)
        # obs device-completion timing (block-until-ready spans when the
        # tracer is enabled; passthrough otherwise) — wrapped AFTER the
        # CountingJit assignments so those call sites stay intact
        self._full_jit = obs.ProgramTimer(self._full_jit,
                                          "fleet.program.full")
        self._incr_jit = obs.ProgramTimer(self._incr_jit,
                                          "fleet.program.incr")
        self._mso_jit = obs.ProgramTimer(self._mso_jit,
                                         "fleet.program.mso")
        # a block spans the whole mesh: cfg.slots slots per device
        self._slots_total = cfg.slots * self._ndev
        self._dtype = jnp.asarray(0.0).dtype
        self._studies: Dict[Hashable, _Study] = {}
        self._queue: List[_Study] = []       # awaiting a slot
        self._blocks: List[_Block] = []
        self._base_key = jax.random.PRNGKey(0)
        # economy counters
        self.n_full_refits = 0
        self.n_incremental = 0
        self.n_fallbacks = 0
        self.n_steps = 0
        self.n_admissions = 0
        self.n_migrations = 0
        self.n_migrations_intra = 0      # re-admitted on the same device
        self.n_migrations_cross = 0      # ... on a different device
        # robustness counters
        self.n_rejected = 0              # admissions refused (fleet full)
        self.n_shed = 0                  # queued studies past deadline
        self.n_quarantined = 0           # observations dropped as poison
        self.n_parked = 0                # studies retired by quarantine
        self.n_retries = 0               # quarantine retry refit launches
        self.n_retry_backoffs = 0        # backoff sleeps taken
        self.backoff_total_s = 0.0       # total backoff charged (seconds)

    def _journal(self, record: dict) -> None:
        if self.journal is not None:
            self.journal.append(record)

    # ----------------------------------------------------------- host api
    def add_study(self, sid: Hashable,
                  deadline: Optional[float] = None) -> None:
        """Register a study; it is admitted to a slot at the next trial
        boundary (step) once it has observations.

        Backpressure: raises :class:`FleetFullError` when the live-study
        or registration-queue caps are hit.  ``deadline`` (absolute
        ``time.monotonic()`` value, default now + ``admission_timeout``)
        bounds how long the study may wait queued for a slot before being
        load-shed."""
        if sid in self._studies:
            raise ValueError(f"study {sid!r} already registered")
        cfg = self.cfg
        live = sum(1 for s in self._studies.values()
                   if s.shed is None and s.parked is None)
        reason = None
        if cfg.max_studies is not None and live >= cfg.max_studies:
            reason = (f"fleet full: {live} live studies "
                      f"(max_studies={cfg.max_studies})")
        elif (cfg.max_queue is not None
                and len(self._queue) >= cfg.max_queue):
            reason = (f"admission queue full: {len(self._queue)} waiting "
                      f"(max_queue={cfg.max_queue})")
        if reason is not None:
            self.n_rejected += 1
            self._journal({"op": "reject", "sid": sid, "reason": reason})
            obs.instant("fleet.reject", sid=str(sid), reason=reason)
            raise FleetFullError(reason)
        st = _Study(sid)
        if deadline is None and cfg.admission_timeout is not None:
            deadline = time.monotonic() + cfg.admission_timeout
        st.deadline = deadline
        self._studies[sid] = st
        self._queue.append(st)

    def observe(self, sid: Hashable, x_unit, y: float,
                tag: Optional[Hashable] = None) -> None:
        """Append one observation (unit-cube x, raw minimized y).  ``tag``
        is the caller's trial id, carried so a later quarantine can name
        the offending trial.

        Guardrail: non-finite values are refused here — one NaN in a slot
        row would poison the stacked standardization/gram for the whole
        block and stall the shared lockstep ``while_loop``s."""
        st = self._studies[sid]
        x_unit = np.asarray(x_unit, np.float64).reshape(self.cfg.dim)
        y = float(y)
        if not (np.all(np.isfinite(x_unit)) and np.isfinite(y)):
            raise ValueError(
                f"study {sid!r}: non-finite observation "
                f"(trial {tag!r}, y={y!r}) — report evaluation failures "
                f"with failed=True; they must never reach GP data")
        st.xs.append(x_unit)
        st.ys.append(y)
        st.tags.append(tag)
        blk = st.block
        if blk is None:
            return
        if pad_bucket_for(st.n, self.cfg.pad_bucket) > blk.bucket:
            # bucket migration: journal, then evict and re-admit
            # (compacted into a larger block) at the next trial boundary
            self.n_migrations += 1
            self._journal({"op": "migrate", "sid": sid, "n": st.n})
            obs.instant("fleet.migrate", sid=str(sid), n=st.n)
            self._evict(st)
        else:
            i = st.n - 1
            blk.x = blk._pin(blk.x.at[st.slot, i].set(
                jnp.asarray(x_unit, blk.x.dtype)))
            blk.y = blk._pin(blk.y.at[st.slot, i].set(float(y)))

    def request_suggest(self, sid: Hashable, key: Optional[Array] = None,
                        fit_seed: Optional[int] = None) -> None:
        """Enqueue one suggest for ``sid`` (no-op if one is already
        pending or an uncollected result is waiting).  ``key`` defaults
        to the fleet's per-study stream ``fold_in(fold_in(base,
        study), trial)``; ``fit_seed`` to the trial counter."""
        st = self._studies[sid]
        if st.shed is not None or st.parked is not None:
            state = "shed" if st.shed is not None else "parked"
            raise FleetStudyError(
                f"study {sid!r} left the fleet ({state}): "
                f"{st.shed or st.parked}")
        if st.pending is not None or st.result is not None:
            return
        if key is None:
            # crc32, not hash(): string sids must give the same stream in
            # every process (hash() is salted per interpreter)
            sid_tag = zlib.crc32(repr(sid).encode()) & 0x7FFFFFFF
            skey = jax.random.fold_in(self._base_key, sid_tag)
            key = jax.random.fold_in(skey, st.trial)
        if fit_seed is None:
            fit_seed = st.trial
        st.pending = (key, int(fit_seed))

    def pop_result(self, sid: Hashable
                   ) -> Optional[Tuple[np.ndarray, SuggestInfo]]:
        """Collect (and clear) the study's suggestion, if ready."""
        st = self._studies[sid]
        res, st.result = st.result, None
        return res

    def cancel_request(self, sid: Hashable) -> bool:
        """Withdraw a study's pending suggest request (deadline shed at
        the service layer): frees the slot's per-step reservation so the
        next block step does no work for it.  An already-computed but
        uncollected result is discarded too — safe, because suggest keys
        are caller-derived, so re-requesting with the same key and the
        same observations recomputes the identical suggestion.  Returns
        whether anything was actually withdrawn."""
        st = self._studies[sid]
        had = st.pending is not None or st.result is not None
        st.pending = None
        st.result = None
        return had

    def suggest(self, sid: Hashable, key: Optional[Array] = None,
                fit_seed: Optional[int] = None
                ) -> Tuple[np.ndarray, SuggestInfo]:
        """Synchronous ask for one study: request → step → collect (other
        studies' pending requests are batched into the same step)."""
        self.request_suggest(sid, key, fit_seed)
        self.step()
        res = self.pop_result(sid)
        assert res is not None
        if isinstance(res, FleetStudyError):
            raise res
        return res

    def study_theta(self, sid: Hashable) -> Optional[np.ndarray]:
        """The study's last fully-refit θ (for snapshots), or None if no
        full refit has committed yet."""
        st = self._studies[sid]
        if st.block is not None and st.has_theta:
            return np.asarray(st.block.theta[st.slot])
        return None if not st.has_theta else st.theta_host

    def restore_theta(self, sid: Hashable, theta) -> None:
        """Re-seed a (not yet admitted) study's warm-start θ — the
        recovery path replays journaled full-refit θs through here so a
        post-recovery warm-started refit matches the uninterrupted run
        bit-for-bit (same mechanism as the migration theta_host carry)."""
        st = self._studies[sid]
        st.theta_host = np.asarray(theta, np.float64)
        st.has_theta = True

    def study_state(self, sid: Hashable) -> Tuple[str, Optional[str]]:
        """(state, reason): ``live`` / ``queued`` with reason None, or
        ``shed`` / ``parked`` with the recorded reason — callers poll this
        to decide when to degrade to the solo path."""
        st = self._studies[sid]
        if st.parked is not None:
            return "parked", st.parked
        if st.shed is not None:
            return "shed", st.shed
        return ("live", None) if st.block is not None else ("queued", None)

    def step(self) -> int:
        """One trial boundary: admit queued studies, then run one fused
        program set per block holding pending requests.  Returns the
        number of suggestions produced."""
        self._admit()
        for st in self._queue:
            if st.pending is not None:
                st.pending = None      # drop the bad request: one broken
                raise ValueError(      # study must not wedge the fleet
                    f"study {st.sid!r} requested suggest() with "
                    f"{st.n} observations; needs >= 2")
        tr = obs.get()
        t0 = tr.now_us() if tr is not None else 0.0
        served = 0
        for blk in self._blocks:
            with obs.span("fleet.step_block", bucket=blk.bucket):
                served += self._step_block(blk)
        if tr is not None and served:
            tr.record_span("fleet.step", t0, tr.now_us() - t0,
                           served=served, n_blocks=len(self._blocks))
        self.n_steps += 1 if served else 0
        return served

    def stats_snapshot(self) -> dict:
        n_compiles = (self._full_jit.n_compiles + self._incr_jit.n_compiles
                      + self._mso_jit.n_compiles)
        return {
            "n_studies": len(self._studies),
            "n_blocks": len(self._blocks),
            "n_full_refits": self.n_full_refits,
            "n_incremental": self.n_incremental,
            "n_fallbacks": self.n_fallbacks,
            "n_steps": self.n_steps,
            "n_admissions": self.n_admissions,
            "n_migrations": self.n_migrations,
            "n_migrations_intra": self.n_migrations_intra,
            "n_migrations_cross": self.n_migrations_cross,
            "n_rejected": self.n_rejected,
            "n_shed": self.n_shed,
            "n_quarantined": self.n_quarantined,
            "n_parked": self.n_parked,
            "n_retries": self.n_retries,
            "n_retry_backoffs": self.n_retry_backoffs,
            "backoff_total_s": round(self.backoff_total_s, 6),
            "n_devices": self._ndev,
            "slots_per_device": self._device_occupancy(),
            "queue_depth": len(self._queue),
            "n_full_compiles": self._full_jit.n_compiles,
            "n_incr_compiles": self._incr_jit.n_compiles,
            "n_mso_compiles": self._mso_jit.n_compiles,
            "n_fleet_compiles": n_compiles,
            "retraces": retrace_report({"full": self._full_jit,
                                        "incr": self._incr_jit,
                                        "mso": self._mso_jit}),
        }

    # ------------------------------------------------------- scheduler
    def _slot_device(self, slot: int) -> int:
        """Mesh device owning ``slot``: NamedSharding splits the slot
        axis into ndev contiguous shards of ``cfg.slots`` rows each."""
        return slot // self.cfg.slots

    def _device_occupancy(self) -> List[int]:
        """Live studies resident on each mesh device (all blocks)."""
        occ = [0] * self._ndev
        for blk in self._blocks:
            for s, st in enumerate(blk.studies):
                if st is not None:
                    occ[self._slot_device(s)] += 1
        return occ

    def _pick_slot(self, bucket: int) -> Optional[Tuple["_Block", int]]:
        """Balanced admission: among free slots in ``bucket``-blocks, take
        the one whose device holds the fewest live studies (ties: earliest
        block, lowest slot — on a 1-device mesh this degenerates to the
        PR-3 first-free-slot rule)."""
        occ = self._device_occupancy()
        best = None
        for bi, bl in enumerate(self._blocks):
            if bl.bucket != bucket:
                continue
            for s, cur in enumerate(bl.studies):
                if cur is None:
                    key = (occ[self._slot_device(s)], bi, s)
                    if best is None or key < best[1]:
                        best = ((bl, s), key)
        return None if best is None else best[0]

    def _admit(self) -> None:
        still: List[_Study] = []
        now = time.monotonic()
        for st in self._queue:
            if st.shed is not None or st.parked is not None:
                continue                 # left the fleet while queued
            if st.n < 1:                 # nothing to pad yet: stay queued
                still.append(st)
                continue
            bucket = pad_bucket_for(st.n, self.cfg.pad_bucket)
            pick = self._pick_slot(bucket)
            if pick is None:
                if (self.cfg.max_blocks is not None
                        and len(self._blocks) >= self.cfg.max_blocks):
                    # no slot and no room to grow: shed waiters past
                    # their admission deadline, keep the rest queued
                    if st.deadline is not None and now > st.deadline:
                        self._shed(st, "admission deadline exceeded "
                                   f"({len(self._blocks)} blocks full)")
                    else:
                        still.append(st)
                    continue
                blk = _Block(self.cfg, bucket, self._dtype,
                             self._slot_sharding, self._slots_total)
                self._blocks.append(blk)
                occ = self._device_occupancy()
                slot = min(range(self._slots_total),
                           key=lambda s: (occ[self._slot_device(s)], s))
            else:
                blk, slot = pick
            self._install(st, blk, slot)
            self.n_admissions += 1
        self._queue = still

    def _shed(self, st: _Study, reason: str) -> None:
        """Load-shed a queued study (never one holding a slot): it stops
        being schedulable; the owning sampler degrades to the solo path
        when it sees the state (``study_state``)."""
        self.n_shed += 1
        self._journal({"op": "shed", "sid": st.sid, "reason": reason})
        obs.instant("fleet.shed", sid=str(st.sid), reason=reason)
        st.shed = reason
        st.pending = None

    def shed_study(self, sid: Hashable, reason: str) -> None:
        """Mark a registered study as load-shed (journal-replay path:
        recovery re-applies shed records through here)."""
        st = self._studies[sid]
        if st.block is not None:
            self._clear_slot(st)
        if st.shed is None:
            self._shed(st, reason)

    def _install(self, st: _Study, blk: _Block, slot: int) -> None:
        """Host-side state compaction: copy the study's live observations
        into the block's padded slot row (θ carried for warm starts).  On
        a mesh this IS the cross-device move — the compacted row lands on
        whichever device owns the target slot."""
        n = st.n
        x_row = np.array(blk.idle_x)
        x_row[:n] = np.stack(st.xs)
        y_row = np.zeros((blk.bucket,))
        y_row[:n] = st.ys
        blk.x = blk._pin(blk.x.at[slot].set(jnp.asarray(x_row,
                                                        blk.x.dtype)))
        blk.y = blk._pin(blk.y.at[slot].set(jnp.asarray(y_row,
                                                        blk.y.dtype)))
        if st.theta_host is not None:
            blk.theta = blk._pin(blk.theta.at[slot].set(
                jnp.asarray(st.theta_host, blk.theta.dtype)))
        self._journal({"op": "admit", "sid": st.sid,
                       "bucket": blk.bucket, "slot": slot, "n": n})
        obs.instant("fleet.admit", sid=str(st.sid), bucket=blk.bucket,
                    slot=slot, n=n)
        blk.studies[slot] = st
        st.block, st.slot = blk, slot
        if st.from_device is not None:       # bucket-growth re-admission
            if self._slot_device(slot) == st.from_device:
                self.n_migrations_intra += 1
            else:
                self.n_migrations_cross += 1
            st.from_device = None

    def _clear_slot(self, st: _Study) -> None:
        """Free the study's slot: save θ for a warm start, reset the row
        to the benign idle pattern (the _FAR invariant holds for every
        non-live slot, whatever removed its study)."""
        blk, s = st.block, st.slot
        if st.has_theta:
            st.theta_host = np.asarray(blk.theta[s])
        dt = blk.x.dtype
        blk.x = blk._pin(blk.x.at[s].set(jnp.asarray(blk.idle_x, dt)))
        blk.y = blk._pin(blk.y.at[s].set(jnp.zeros((blk.bucket,), dt)))
        blk.theta = blk._pin(blk.theta.at[s].set(
            jnp.asarray(blk.theta0, dt)))
        eye = jnp.eye(blk.bucket, dtype=dt)
        blk.chol = blk._pin(blk.chol.at[s].set(eye))
        blk.alpha = blk._pin(blk.alpha.at[s].set(
            jnp.zeros((blk.bucket,), dt)))
        if blk.kinv is not None:
            blk.kinv = blk._pin(blk.kinv.at[s].set(eye))
        blk.studies[s] = None
        st.block, st.slot = None, -1
        st.from_device = self._slot_device(s)
        st.has_factor = False            # the factor dies with the bucket

    def _evict(self, st: _Study) -> None:
        """Bucket migration: free the slot and re-queue for re-admission
        (compacted) into a larger block."""
        self._clear_slot(st)
        self._queue.append(st)

    def _park(self, st: _Study, reason: str) -> None:
        """Retire a study the fleet cannot serve (quarantine retries
        exhausted, or too few clean observations left): free its slot and
        fail the pending request through the result mailbox."""
        self.n_parked += 1
        self._journal({"op": "park", "sid": st.sid, "reason": reason})
        obs.instant("fleet.park", sid=str(st.sid), reason=reason)
        if st.block is not None:
            self._clear_slot(st)
        st.parked = reason
        st.pending = None
        st.result = FleetStudyError(f"study {st.sid!r} parked: {reason}")

    def _quarantine_newest(self, st: _Study, reason: str) -> None:
        """Drop the study's newest observation from GP data with a
        recorded reason (WAL first), resetting its slot row entry to the
        benign idle value; park the study if too few clean observations
        remain."""
        k = st.n - 1
        x_bad, y_bad, tag = st.xs[-1], st.ys[-1], st.tags[-1]
        self.n_quarantined += 1
        self._journal({"op": "quarantine", "sid": st.sid, "trial": tag,
                       "x": x_bad.tolist(), "y": y_bad, "reason": reason})
        obs.instant("fleet.quarantine", sid=str(st.sid),
                    trial=str(tag), reason=reason)
        st.xs.pop()
        st.ys.pop()
        st.tags.pop()
        blk, s = st.block, st.slot
        if blk is not None:
            dt = blk.x.dtype
            blk.x = blk._pin(blk.x.at[s, k].set(
                jnp.asarray(blk.idle_x[k], dt)))
            blk.y = blk._pin(blk.y.at[s, k].set(jnp.asarray(0.0, dt)))
        st.n_fit = min(st.n_fit, st.n)
        st.has_factor = False        # the factor summed the dropped row
        if self.on_quarantine is not None:
            self.on_quarantine(st.sid, tag, reason)
        if st.n < 2 and st.block is not None:
            self._park(st, f"only {st.n} clean observations "
                       f"after quarantine")

    def _step_block(self, blk: _Block) -> int:
        cfg = self.cfg
        req = [(s, st) for s, st in enumerate(blk.studies)
               if st is not None and st.pending is not None]
        if not req:
            return 0
        for s, st in req:
            if st.n < 2:
                st.pending = None      # drop, don't wedge (see step())
                raise ValueError(f"suggest() for study {st.sid!r} needs "
                                 f">= 2 observations, have {st.n}")
        S = self._slots_total
        nv = jnp.asarray(blk.n_valid())
        sids = [None if s is None else s.sid for s in blk.studies]

        # refit_interval=k ⇒ a full MAP refit every k-th suggest (per
        # slot; k=1 disables incremental updates) — same predicate as
        # AskEngine.suggest
        kind: Dict[int, str] = {}
        do_incr = np.zeros((S,), bool)
        for s, st in req:
            incremental = (st.has_factor and st.n - st.n_fit == 1
                           and st.since_refit < cfg.refit_interval - 1)
            if incremental:
                do_incr[s] = True
                kind[s] = "incremental"
            else:
                kind[s] = "full"

        if do_incr.any():
            chol, alpha, kinv, ok = self._incr_jit(
                blk.x, blk.y, nv, blk.theta, blk.chol, blk.alpha,
                blk.kinv, jnp.asarray(do_incr))
            blk.chol, blk.alpha, blk.kinv = chol, alpha, kinv
            ok = np.asarray(ok)
            if self.fault_injector is not None:
                ok = self.fault_injector.incr_ok(ok, sids)
            for s, st in req:
                if not do_incr[s]:
                    continue
                if ok[s]:
                    st.since_refit += 1
                    self.n_incremental += 1
                else:                    # exactness fallback: refit for real
                    kind[s] = "fallback"
                    self.n_fallbacks += 1
                    self.engine.record_refit_fallback()

        full_slots = [s for s, _ in req if kind[s] != "incremental"]
        if full_slots:
            dt = blk.x.dtype
            R = cfg.gp_fit_restarts
            # ONE warm-start snapshot for the whole retry loop: a retry
            # must not warm-start from the unhealthy θ it is retrying
            theta_host = np.asarray(blk.theta)
            tlo, tup = theta_bounds(cfg.dim, dt)
            pending_full = list(full_slots)
            for attempt in range(cfg.quarantine_retries + 1):
                pf = set(pending_full)
                rows = []
                for s in range(S):
                    st = blk.studies[s]
                    if s in pf:
                        init = None
                        if cfg.warm_start and st.has_theta:
                            init = unpack_theta(
                                jnp.asarray(theta_host[s], dt), cfg.dim)
                        rows.append(theta_init_grid(
                            cfg.dim, dt, R, st.pending[1], init=init))
                    else:                # masked-out slot: benign inits
                        rows.append(theta_init_grid(cfg.dim, dt, R, 0))
                thetas = jnp.stack(rows)            # (S, R, P)
                do_full = np.zeros((S,), bool)
                do_full[pending_full] = True
                nv = jnp.asarray(blk.n_valid())
                theta, chol, alpha, kinv, okf = self._full_jit(
                    blk.x, blk.y, nv, thetas,
                    jnp.broadcast_to(tlo, thetas.shape),
                    jnp.broadcast_to(tup, thetas.shape),
                    jnp.asarray(do_full), blk.theta, blk.chol, blk.alpha,
                    blk.kinv)
                blk.theta, blk.chol, blk.alpha, blk.kinv = \
                    theta, chol, alpha, kinv
                fi = self.fault_injector
                if fi is not None and hasattr(fi, "full_delay"):
                    # injected refit latency: charge the sleep hook (a
                    # virtual clock in tests) — data/timing only, the
                    # compiled program is untouched
                    d = fi.full_delay([blk.studies[s].sid
                                       for s in pending_full])
                    if d > 0.0:
                        self._sleep(d)
                okf = np.asarray(okf)
                if self.fault_injector is not None:
                    okf = self.fault_injector.full_ok(okf, sids)
                bad = [s for s in pending_full if not okf[s]]
                for s in pending_full:
                    if okf[s]:
                        st = blk.studies[s]
                        st.since_refit = 0
                        st.has_theta = True
                        self.n_full_refits += 1
                        if self.journal is not None:
                            self._journal({
                                "op": "refit", "sid": st.sid,
                                "theta": np.asarray(
                                    blk.theta[s]).tolist()})
                if not bad:
                    break
                # quarantine: drop each unhealthy slot's newest
                # observation (the likeliest poison) and refit just those
                # slots — a pure data change (same shapes), so retries
                # reuse the same compiled program
                nxt = []
                for s in bad:
                    st = blk.studies[s]
                    self._quarantine_newest(
                        st, f"full refit unhealthy "
                        f"(attempt {attempt + 1})")
                    if st.block is None:     # parked mid-quarantine
                        continue
                    if attempt < cfg.quarantine_retries:
                        nxt.append(s)
                    else:
                        self._park(st, "quarantine retries exhausted "
                                   f"({cfg.quarantine_retries + 1} "
                                   f"unhealthy refits)")
                pending_full = nxt
                if not pending_full:
                    break
                # bounded exponential backoff (with jitter) before the
                # retry: a persistently unhealthy slot must not hot-spin
                # full refits back-to-back.  Host-side only — the retry
                # still reuses the same compiled program.
                self.n_retries += len(pending_full)
                if cfg.retry_backoff_base > 0.0:
                    delay = min(cfg.retry_backoff_base * (2.0 ** attempt),
                                cfg.retry_backoff_cap)
                    delay *= 1.0 + (cfg.retry_backoff_jitter
                                    * float(self._backoff_rng.random()))
                    self.n_retry_backoffs += 1
                    self.backoff_total_s += delay
                    self._journal({"op": "backoff", "attempt": attempt + 1,
                                   "delay_s": delay,
                                   "sids": [blk.studies[s].sid
                                            for s in pending_full]})
                    obs.instant("fleet.backoff", attempt=attempt + 1,
                                delay_s=delay, n_studies=len(pending_full))
                    self._sleep(delay)
            nv = jnp.asarray(blk.n_valid())
            # parked studies dropped their requests mid-phase
            req = [(s, st) for s, st in req if st.pending is not None]
            if not req:
                return 0

        keys = np.zeros((S, 2), np.uint32)
        for s, st in req:
            keys[s] = np.asarray(st.pending[0])
        best_x, stats = self._mso_jit(
            jnp.asarray(keys), blk.x, blk.y, nv, blk.theta, blk.chol,
            blk.alpha, blk.kinv)
        bx = np.asarray(best_x)                     # ONE (S, D) transfer
        k_arr, ev_arr, rounds, bacq = stats
        # rounds is per-slot: each slot reports its own device's lockstep
        # round count (devices loop independently on a mesh; on one
        # device every slot sees the same shared count)
        rounds = np.asarray(rounds)
        for s, st in req:
            st.n_fit = st.n
            st.has_factor = True
            st.trial += 1
            info = SuggestInfo(kind=kind[s], n_iters=k_arr[s],
                               n_evals=ev_arr[s], rounds=rounds[s],
                               best_acq=bacq[s])
            st.result = (bx[s], info)
            st.pending = None
        # frozen idle/non-requesting rows are the fleet's padding
        # analogue: only requesters' evals count as live points
        ev_live = np.zeros((S, cfg.n_restarts), np.int64)
        for s, _ in req:
            ev_live[s] = np.asarray(ev_arr[s])
        self.engine.record_lockstep_economy(S * cfg.n_restarts,
                                            int(rounds.max()), ev_live)
        return len(req)

    # ------------------------------------------------------- device side
    def _full_impl(self, x, y, n_valid, thetas, tlo, tup, do_full,
                   theta_old, chol_old, alpha_old, kinv_old):
        """Vmapped full refit over the slot axis; ``do_full`` masks which
        slots commit (the rest keep their previous state).

        Also returns a per-slot health flag: a refit that produced
        non-finite θ/α or a broken Cholesky (non-PD gram → NaN or
        non-positive diagonal) must NOT be served — the unhealthy slot
        keeps its previous (benign) state and the host quarantines the
        likeliest poison observation and retries.  Masked-out slots are
        vacuously healthy."""
        cfg = self.cfg

        def one(x_s, y_s, nv, th, lo, up):
            _, _, theta, chol, alpha, kinv = refit_core(
                x_s, y_s, nv, th, lo, up, dim=cfg.dim, kernel=cfg.kernel,
                backend=cfg.backend, fit_opts=self._fit_opts)
            return theta, chol, alpha, kinv

        theta_n, chol_n, alpha_n, kinv_n = jax.vmap(one)(
            x, y, n_valid, thetas, tlo, tup)
        diag = jnp.diagonal(chol_n, axis1=-2, axis2=-1)
        healthy = (jnp.all(jnp.isfinite(theta_n), axis=-1)
                   & jnp.all(jnp.isfinite(alpha_n), axis=-1)
                   & jnp.all(jnp.isfinite(diag) & (diag > 0.0), axis=-1))
        ok = healthy | ~do_full

        def sel(new, old):
            m = (do_full & ok).reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)

        kinv = None if kinv_old is None else sel(kinv_n, kinv_old)
        return (sel(theta_n, theta_old), sel(chol_n, chol_old),
                sel(alpha_n, alpha_old), kinv, ok)

    def _incr_impl(self, x, y, n_valid, theta, chol_old, alpha_old,
                   kinv_old, do_incr):
        """Vmapped rank-one refit over the slot axis; a slot commits only
        when requested (``do_incr``) AND its Schur complement is sound."""
        cfg = self.cfg

        def one(x_s, y_s, nv, th, ch, ki):
            _, _, _, chol_new, alpha, kinv_new, ok = incr_core(
                x_s, y_s, nv, th, ch, ki, dim=cfg.dim, kernel=cfg.kernel)
            return chol_new, alpha, kinv_new, ok

        chol_n, alpha_n, kinv_n, ok = jax.vmap(one)(
            x, y, n_valid, theta, chol_old, kinv_old)
        commit = do_incr & ok

        def sel(new, old):
            m = commit.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)

        kinv = None if kinv_old is None else sel(kinv_n, kinv_old)
        return sel(chol_n, chol_old), sel(alpha_n, alpha_old), kinv, ok

    def _mso_impl(self, keys, x, y, n_valid, theta, chol, alpha, kinv):
        """The fleet MSO tail: per-slot restart sampling feeds ONE
        (S, B, D) lockstep solve; per-slot argmax selects suggestions."""
        cfg = self.cfg
        b = x.shape[1]

        def prep(key, x_s, y_s, nv):
            valid = jnp.arange(b) < nv
            y_std, _, _ = standardize_masked(-y_s, valid)
            x0, best_val = restart_points(key, x_s, y_std, valid,
                                          cfg.n_restarts)
            return y_std, x0, best_val

        y_std, x0, best_val = jax.vmap(prep)(keys, x, y, n_valid)
        params = jax.vmap(lambda th: unpack_theta(th, cfg.dim))(theta)
        gp = GPState(x_train=x, y_train=y_std, params=params, chol=chol,
                     alpha=alpha, kernel=cfg.kernel, kinv=kinv)
        fun = self.engine.fleet_device_fun((gp, best_val), self._plan)
        res = lbfgsb_minimize(fun, x0, jnp.zeros_like(x0),
                              jnp.ones_like(x0), cfg.mso)
        best = jnp.argmax(-res.f, axis=1)                     # (S,)
        best_x = jnp.take_along_axis(
            res.x, best[:, None, None], axis=1)[:, 0]         # (S, D)
        best_acq = -jnp.take_along_axis(res.f, best[:, None], axis=1)[:, 0]
        # per-slot rounds: under shard_map this is the owning device's
        # (independent) round count, and every output leads with the
        # slot axis so one P(study) out-spec covers the whole pytree
        rounds = jnp.full((x.shape[0],), res.rounds)
        return best_x, (res.k, res.n_evals, rounds, best_acq)
