"""The fused device-resident ask() pipeline — one compiled suggest program.

PR 1 made the MSO inner solve device-resident (``dbe_vec``); this module
fuses the *rest* of a BO trial around it.  One :class:`AskEngine` owns the
whole suggest path as two jitted programs per GP size bucket:

* **full program** — masked standardize → multi-start MAP hyperparameter
  fit (``gp.fit.fit_padded_core``, θ warm-started from the previous
  trial) → K⁻¹ materialization (fused-posterior backends) → device-side
  restart sampling → lockstep L-BFGS-B MSO → argmax.  Runs at bucket
  boundaries, every ``refit_interval`` trials, and as the exactness
  fallback.
* **incremental program** — masked standardize → rank-one Cholesky /
  bordered-K⁻¹ append (``gp.fit.incremental_update``, O(n²), fixed θ) →
  the same restart sampling → MSO → argmax.  Runs on every other trial:
  the O(n³) refactorization and the MAP optimization never execute.

Trial-to-trial state (padded X/y buffers, θ, Cholesky factor, K⁻¹) lives
on device between calls; the incremental program *donates* the O(n²)
factor buffers so steady-state trials update them in place (accelerator
backends) and transfer only ``best_x`` (plus scalar stats) back to host.
Both programs run through :class:`CountingJit`, so "compiles per run"
stays an exact, testable O(#size-buckets) metric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import cho_solve

from repro.core.lbfgsb import LbfgsbOptions, lbfgsb_minimize
from repro.engine.cache import CountingJit, retrace_report
from repro.obs import trace as obs
from repro.engine.engine import EvalEngine
from repro.engine.plan import EvalPlan
from repro.gp.fit import (FIT_OPTS, _FAR, fit_padded_core,
                          incremental_update, pad_bucket_for,
                          standardize_masked, theta_bounds,
                          theta_init_grid, unpack_theta)
from repro.gp.gpr import GPState
from repro.gp.kernels import KernelParams

Array = jax.Array

# paper-style MSO defaults (mirrors core.mso.MsoOptions)
_MSO_DEFAULT = LbfgsbOptions(m=10, maxiter=200, pgtol=1e-2, ftol=0.0,
                             maxls=25)


@dataclass(frozen=True)
class AskConfig:
    """Static description of one fused ask pipeline (hashable; everything
    here is baked into the compiled programs via closure, never traced)."""
    dim: int
    n_restarts: int = 10             # B: incumbent + (B-1) uniform
    kernel: str = "matern52"
    backend: str = "xla"             # resolved posterior backend
    pad_bucket: int = 32             # GP size-bucket quantum
    refit_interval: int = 8          # full MAP refit cadence (≥1)
    warm_start: bool = True          # seed the MAP fit from previous θ
    gp_fit_restarts: int = 2
    gp_fit_maxiter: int = 60
    mso: LbfgsbOptions = _MSO_DEFAULT

    def __post_init__(self):
        if self.refit_interval < 1:
            raise ValueError("refit_interval must be >= 1")
        if self.n_restarts < 2:
            raise ValueError("n_restarts must be >= 2")


class SuggestInfo(NamedTuple):
    """Per-trial diagnostics (small device scalars; convert lazily)."""
    kind: str            # "full" | "incremental" | "fallback"
    n_iters: Array       # (B,) QN iterations per restart
    n_evals: Array       # (B,) active objective evals per restart
    rounds: Array        # ()  batched evaluation rounds
    best_acq: Array      # ()  acquisition value at the suggestion


# ---------------------------------------------------------------------------
# study-axis cores — the per-study halves of the suggest pipeline, exposed
# as pure functions of ONE padded study so the fleet plane (engine/fleet.py)
# can stack whole studies along a leading axis with jax.vmap while AskEngine
# keeps calling them unbatched.  All three are vmap-safe: masked reductions
# only, no data-dependent shapes.
# ---------------------------------------------------------------------------

def refit_core(x, y, n_valid, thetas, tlo, tup, *, dim: int, kernel: str,
               backend: str, fit_opts: LbfgsbOptions):
    """Full-refit core: masked standardize → multi-start MAP fit → (for
    fused posterior backends) K⁻¹ materialization.

    Returns ``(y_std, valid, theta, chol, alpha, kinv)`` with ``kinv``
    ``None`` on the ``"xla"`` backend.
    """
    b = x.shape[0]
    valid = jnp.arange(b) < n_valid
    y_std, _, _ = standardize_masked(-y, valid)
    theta, chol, alpha, _ = fit_padded_core(
        x, y_std, valid, thetas, tlo, tup,
        dim=dim, kernel=kernel, opts=fit_opts)
    kinv = None
    if backend != "xla":
        kinv = cho_solve((chol, True), jnp.eye(b, dtype=x.dtype))
    return y_std, valid, theta, chol, alpha, kinv


def incr_core(x, y, n_valid, theta, chol, kinv, *, dim: int, kernel: str):
    """Incremental-refit core: masked standardize → rank-one Cholesky /
    bordered-K⁻¹ append at fixed θ (O(n²)).

    Returns ``(y_std, valid, params, chol, alpha, kinv, ok)``; ``ok``
    flags a numerically sound Schur complement (callers fall back to
    :func:`refit_core` when it is False).
    """
    b = x.shape[0]
    valid = jnp.arange(b) < n_valid
    y_std, _, _ = standardize_masked(-y, valid)
    params = unpack_theta(theta, dim)
    chol_new, alpha, kinv_new, ok = incremental_update(
        x, y_std, n_valid, params, chol, kinv, kernel=kernel)
    return y_std, valid, params, chol_new, alpha, kinv_new, ok


def restart_points(key, x, y_std, valid, n_restarts: int):
    """Device-side restart sampling: incumbent + (B−1) uniform draws.

    Returns ``(x0 (B, D), best_val)`` — the per-study restart stack and
    the incumbent (standardized, maximization-scale) objective value.
    """
    masked = jnp.where(valid, y_std, -jnp.inf)
    best_val = jnp.max(masked)
    inc = x[jnp.argmax(masked)]
    rand = jax.random.uniform(key, (n_restarts - 1, x.shape[-1]), x.dtype)
    x0 = jnp.concatenate([inc[None], rand], 0)
    return x0, best_val


class AskEngine:
    """Fused ask(): observe() appends, suggest() runs one device program."""

    def __init__(self, engine: EvalEngine, cfg: AskConfig,
                 fault_injector=None):
        self.engine = engine
        self.cfg = cfg
        # chaos hook (tests/faults.py): may veto the incremental-update
        # ok flag to force the full-refit fallback deterministically
        self.fault_injector = fault_injector
        self._plan = EvalPlan.for_batch(cfg.n_restarts, cfg.dim)
        self._fit_opts = FIT_OPTS._replace(maxiter=cfg.gp_fit_maxiter)
        self._full_jit = CountingJit(self._full_impl)
        # donate the O(n²) factor buffers: steady-state trials rewrite
        # them in place instead of allocating fresh ones
        self._incr_jit = CountingJit(self._incr_impl, donate_argnums=(5, 6))
        # device-completion timing (block-until-ready spans when the obs
        # tracer is enabled; passthrough otherwise) — wraps the programs
        # AFTER construction so the CountingJit call sites stay intact
        self._full_jit = obs.ProgramTimer(self._full_jit,
                                          "ask.program.full")
        self._incr_jit = obs.ProgramTimer(self._incr_jit,
                                          "ask.program.incr")

        # trial-to-trial device state
        self._x: Optional[Array] = None       # (b, D) padded observations
        self._y: Optional[Array] = None       # (b,)  raw objective values
        self._n = 0                           # live observation count
        self._theta: Optional[Array] = None   # (P,) fitted log-hypers
        self._chol: Optional[Array] = None    # (b, b) padded factor
        self._alpha: Optional[Array] = None   # (b,)
        self._kinv: Optional[Array] = None    # (b, b) (fused backends)
        self._n_fit = 0                       # observations in the factor
        self._since_refit = 0
        # economy counters
        self.n_full_refits = 0
        self.n_incremental = 0
        self.n_fallbacks = 0

    # ----------------------------------------------------------- host api
    @property
    def n_obs(self) -> int:
        return self._n

    @property
    def bucket(self) -> int:
        return 0 if self._x is None else self._x.shape[0]

    def observe(self, x_unit: np.ndarray, y: float) -> None:
        """Append one observation (unit-cube x, raw minimized y)."""
        x_unit = np.asarray(x_unit).reshape(self.cfg.dim)
        n_new = self._n + 1
        b_needed = pad_bucket_for(n_new, self.cfg.pad_bucket)
        if self._x is None or b_needed > self._x.shape[0]:
            self._grow(b_needed)
        self._x = self._x.at[self._n].set(
            jnp.asarray(x_unit, self._x.dtype))
        self._y = self._y.at[self._n].set(float(y))
        self._n = n_new

    def _grow(self, b: int) -> None:
        """Move to a larger pad bucket; invalidates the factor state
        (the next suggest() takes the full-refit program — by design the
        only trials that pay an O(n³) cost or a fresh XLA trace)."""
        D = self.cfg.dim
        dt = self._x.dtype if self._x is not None else jnp.asarray(0.0).dtype
        x = jnp.full((b, D), _FAR, dt) + jnp.arange(b, dtype=dt)[:, None]
        y = jnp.zeros((b,), dt)
        if self._x is not None:
            x = x.at[:self._n].set(self._x[:self._n])
            y = y.at[:self._n].set(self._y[:self._n])
        self._x, self._y = x, y
        self._chol = self._alpha = self._kinv = None

    def suggest(self, key: Array, fit_seed: int
                ) -> Tuple[np.ndarray, SuggestInfo]:
        """One fused ask: returns (unit-cube best_x, diagnostics).

        ``key`` drives the device-side restart sampling; ``fit_seed`` the
        MAP multi-start jitter (matching ``fit_gp(seed=...)``).
        """
        if self._n < 2:
            raise ValueError(
                f"suggest() needs >= 2 observations, have {self._n}")
        tr = obs.get()
        t_start = tr.now_us() if tr is not None else 0.0
        n_valid = jnp.asarray(self._n, jnp.int32)

        # refit_interval=k ⇒ a full MAP refit every k-th suggest
        # (k=1: every trial, i.e. incremental updates disabled)
        incremental = (self._chol is not None
                       and self._n - self._n_fit == 1
                       and self._since_refit < self.cfg.refit_interval - 1)
        kind = "incremental"
        if incremental:
            best_x, chol, alpha, kinv, ok, stats = self._incr_jit(
                key, self._x, self._y, n_valid,
                self._theta, self._chol, self._kinv)
            ok = bool(ok)
            if self.fault_injector is not None:
                ok = bool(self.fault_injector.incr_ok(
                    np.asarray([ok]), [None])[0])
            if ok:
                self._chol, self._alpha, self._kinv = chol, alpha, kinv
                self._since_refit += 1
                self.n_incremental += 1
            else:                     # exactness fallback: refit for real
                self.n_fallbacks += 1
                self.engine.record_refit_fallback()
                incremental = False
                kind = "fallback"

        if not incremental:
            dt = self._x.dtype
            init = None
            if self.cfg.warm_start and self._theta is not None:
                init = unpack_theta(self._theta, self.cfg.dim)
            with obs.span("ask.phase.theta_grid",
                          restarts=self.cfg.gp_fit_restarts):
                thetas = theta_init_grid(self.cfg.dim, dt,
                                         self.cfg.gp_fit_restarts,
                                         fit_seed, init=init)
            tlo, tup = theta_bounds(self.cfg.dim, dt)
            best_x, theta, chol, alpha, kinv, stats = self._full_jit(
                key, self._x, self._y, n_valid, thetas,
                jnp.broadcast_to(tlo, thetas.shape),
                jnp.broadcast_to(tup, thetas.shape))
            self._theta = theta
            self._chol, self._alpha, self._kinv = chol, alpha, kinv
            self._since_refit = 0
            self.n_full_refits += 1
            kind = "full" if kind == "incremental" else kind

        self._n_fit = self._n
        info = SuggestInfo(kind=kind, n_iters=stats[0], n_evals=stats[1],
                           rounds=stats[2], best_acq=stats[3])
        # the in-program lockstep solve bypasses run_lockstep, so feed
        # the shared EngineStats economy counters here
        self.engine.record_lockstep_economy(self.cfg.n_restarts,
                                            info.rounds, info.n_evals)
        if tr is not None:
            tr.record_span("ask.suggest", t_start, tr.now_us() - t_start,
                           kind=kind, n=self._n,
                           bucket=int(self._x.shape[0]))
        return np.asarray(best_x), info

    def gp_state(self) -> GPState:
        """Reconstruct the current fitted GPState (tests/introspection)."""
        if self._chol is None:
            raise ValueError("no fitted state yet")
        valid = jnp.arange(self.bucket) < self._n_fit
        y_std, _, _ = standardize_masked(-self._y, valid)
        return GPState(x_train=self._x, y_train=y_std,
                       params=unpack_theta(self._theta, self.cfg.dim),
                       chol=self._chol, alpha=self._alpha,
                       kernel=self.cfg.kernel, kinv=self._kinv)

    def stats_snapshot(self) -> dict:
        return {
            "n_full_refits": self.n_full_refits,
            "n_incremental": self.n_incremental,
            "n_fallbacks": self.n_fallbacks,
            "n_full_compiles": self._full_jit.n_compiles,
            "n_incr_compiles": self._incr_jit.n_compiles,
            "n_ask_compiles": (self._full_jit.n_compiles
                               + self._incr_jit.n_compiles),
            "retraces": retrace_report({"full": self._full_jit,
                                        "incr": self._incr_jit}),
        }

    # ------------------------------------------------------- device side
    def _mso_tail(self, key, x, y_std, valid, params: KernelParams,
                  chol, alpha, kinv):
        """Shared back half of both programs: restart sampling → lockstep
        MSO → selection.  Mirrors the host pipeline exactly (incumbent +
        (B−1) uniform restarts, LogEI maximization, argmax over final f)."""
        cfg = self.cfg
        gp = GPState(x_train=x, y_train=y_std, params=params, chol=chol,
                     alpha=alpha, kernel=cfg.kernel, kinv=kinv)
        x0, best_val = restart_points(key, x, y_std, valid, cfg.n_restarts)
        fun = self.engine.device_fun((gp, best_val), self._plan)
        res = lbfgsb_minimize(fun, x0, jnp.zeros_like(x0),
                              jnp.ones_like(x0), cfg.mso)
        best = jnp.argmax(-res.f)
        stats = (res.k, res.n_evals, res.rounds, -res.f[best])
        return res.x[best], stats

    def _full_impl(self, key, x, y, n_valid, thetas, tlo, tup):
        D = x.shape[1]
        y_std, valid, theta, chol, alpha, kinv = refit_core(
            x, y, n_valid, thetas, tlo, tup, dim=D, kernel=self.cfg.kernel,
            backend=self.cfg.backend, fit_opts=self._fit_opts)
        params = unpack_theta(theta, D)
        best_x, stats = self._mso_tail(key, x, y_std, valid, params,
                                       chol, alpha, kinv)
        return best_x, theta, chol, alpha, kinv, stats

    def _incr_impl(self, key, x, y, n_valid, theta, chol, kinv):
        D = x.shape[1]
        y_std, valid, params, chol_new, alpha, kinv_new, ok = incr_core(
            x, y, n_valid, theta, chol, kinv,
            dim=D, kernel=self.cfg.kernel)
        best_x, stats = self._mso_tail(key, x, y_std, valid, params,
                                       chol_new, alpha, kinv_new)
        return best_x, chol_new, alpha, kinv_new, ok, stats
