"""Architecture configuration for the LM substrate.

One frozen dataclass covers all ten assigned families; family-specific
fields are zero/empty when unused.  Exact assigned configs live in
``repro/configs/<id>.py``; reduced smoke variants come from ``reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 → d_model // n_heads

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # positional / attention details
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # chatglm: 0.5 ("2d" partial rotary)
    qk_norm: bool = False        # chameleon
    window: int = 0              # local-attention window (hybrid)

    # hybrid (RecurrentGemma): block pattern repeats (rec, rec, attn)
    attn_every: int = 0          # every k-th block is attention; 0 = all attn
    lru_width: int = 0
    conv_width: int = 4

    # ssm (xLSTM): one sLSTM per `slstm_every` blocks, rest mLSTM
    slstm_every: int = 0
    mlstm_chunk: int = 256

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_seq_fraction: float = 0.5   # encoder gets this share of cell seq_len

    # frontend stubs
    frontend: str = "none"       # none | vq_image | audio_frames

    norm: str = "rmsnorm"        # rmsnorm | layernorm
    activation: str = "swiglu"   # swiglu | gelu
    tie_embeddings: bool = False

    dtype: str = "bfloat16"
    # sub-quadratic decode (eligibility for long_500k per DESIGN.md §5)
    sub_quadratic: bool = False

    # runtime knobs (overridable per run, not part of the architecture)
    fsdp: bool = False           # shard params over "data" too (ZeRO-3)
    seq_shard: bool = False      # sequence-parallel residual stream (SP)
    scan_layers: bool = True
    remat: str = "full"          # none | full | dots
    attn_chunk: int = 1024       # XLA chunked-attention query block

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.family == "encdec" and self.n_enc_layers == 0:
            object.__setattr__(self, "n_enc_layers", self.n_layers)
            object.__setattr__(self, "n_dec_layers", self.n_layers)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4 if self.family != "ssm" else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(max(self.n_kv_heads
                               * 4 // max(self.n_heads, 1), 1), 4),
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
        )
        if self.is_moe:
            kw.update(n_experts=8, experts_per_token=2)
        if self.family == "hybrid":
            kw.update(lru_width=128, window=64, n_layers=3)
        if self.family == "ssm":
            kw.update(slstm_every=2, mlstm_chunk=32)
        if self.family == "encdec":
            kw.update(n_enc_layers=2, n_dec_layers=2, n_layers=2)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# parameter counting (for MODEL_FLOPS in the roofline)
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> dict:
    """Approximate parameter counts: total and active-per-token."""
    d, h = cfg.d_model, cfg.head_dim
    attn = d * (cfg.n_heads * h) + 2 * d * (cfg.n_kv_heads * h) \
        + (cfg.n_heads * h) * d

    def mlp_params(ff):
        mult = 3 if cfg.activation == "swiglu" else 2
        return mult * d * ff

    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    if cfg.family == "encdec":
        enc = cfg.n_enc_layers * (attn + mlp_params(cfg.d_ff))
        dec = cfg.n_dec_layers * (2 * attn + mlp_params(cfg.d_ff))
        total = enc + dec + emb
        return {"total": total, "active": total}

    if cfg.is_moe:
        router = cfg.n_layers * d * cfg.n_experts
        experts = cfg.n_layers * cfg.n_experts * mlp_params(cfg.d_ff)
        act_experts = cfg.n_layers * cfg.experts_per_token \
            * mlp_params(cfg.d_ff)
        base = cfg.n_layers * attn + emb + router
        return {"total": base + experts, "active": base + act_experts}

    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        n_rec = cfg.n_layers - n_attn
        lru = cfg.lru_width
        rec_block = 2 * d * lru + lru * d + cfg.conv_width * lru + 3 * lru
        total = (n_attn * attn + n_rec * rec_block
                 + cfg.n_layers * mlp_params(cfg.d_ff) + emb)
        return {"total": total, "active": total}

    if cfg.family == "ssm":
        # mLSTM block: up-proj(2x), qkv in up space, gates, down-proj
        up = 2 * d
        mlstm = d * up * 2 + up * (3 * up // 2) // 1 + up * d
        total = cfg.n_layers * mlstm + emb
        return {"total": total, "active": total}

    total = cfg.n_layers * (attn + mlp_params(cfg.d_ff)) + emb
    return {"total": total, "active": total}
