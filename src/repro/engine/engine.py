"""The device-resident acquisition-evaluation engine.

One object owns everything the four MSO strategies used to re-implement
separately:

* **negated value+grad construction** — the single definition of
  ``(-acq, -∇acq)`` that the scipy coroutine workers, the C-BE flattened
  solver, and the device-resident lockstep solver all consume;
* **shape-bucketed jit caches** — evaluations are padded to an
  :class:`~repro.engine.plan.EvalPlan` bucket so a whole shrinking-active-
  set schedule (and a whole BO run over size-bucketed GP states) runs in a
  handful of compiled executables, with an exact compile counter;
* **pad-or-shrink scheduling** — the host-facing evaluator pads small
  active sets up to a bucket and slices the results back, replacing the
  old ``make_neg_batch_eval`` pad-to-max logic;
* **q-batch layout** — candidates may be joint ``(q, D)`` blocks; the
  engine reshapes between the QN solvers' flat ``(k, q·D)`` view and the
  acquisition's ``(k, q, D)`` view.

The masked-lockstep variant of active-set handling lives in
``core.lbfgsb`` (it is intrinsic to the one-program formulation); the
engine supplies that solver's batched evaluation function from the same
acquisition primitive, so both realizations of "batch the evaluations"
share one evaluation plane.

Construct one engine per acquisition *function* and reuse it across
trials: jit caches key on function identity + shapes, so per-trial data
(fitted GP, incumbent) must flow through ``state`` as a pytree.
``default_engine`` keeps a per-function registry for callers that don't
manage engine lifetimes themselves.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lbfgsb import LbfgsbOptions, LbfgsbResult, lbfgsb_minimize
from repro.engine.cache import CountingJit, retrace_report
from repro.engine.plan import EvalPlan
from repro.obs import trace as obs

Array = jax.Array

# acq_fn(state, X) -> (k,) with X (k, D) [q=1] or (k, q, D) [q>1]
AcqStateFn = Callable[[Any, Array], Array]
# host-facing batched evaluator: (k, q*D) -> ((k,), (k, q*D))
BatchEvalFn = Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]]


@dataclass
class EngineStats:
    """Evaluation/compile economy counters for one engine."""
    n_rounds: int = 0            # host-facing batched evaluation rounds
    n_points: int = 0            # live points evaluated (excl. padding)
    n_padded: int = 0            # padded rows evaluated and discarded
    n_refit_fallbacks: int = 0   # incremental refits demoted to full
    bucket_rounds: Dict[int, int] = field(default_factory=dict)

    def snapshot(self, engine: "EvalEngine") -> Dict[str, Any]:
        return {
            "n_compiles": engine.n_compiles,
            "n_eval_compiles": engine._eval_jit.n_compiles,
            "n_lockstep_compiles": engine._vec_jit.n_compiles,
            "n_rounds": self.n_rounds,
            "n_points": self.n_points,
            "n_padded": self.n_padded,
            "n_refit_fallbacks": self.n_refit_fallbacks,
            "bucket_rounds": dict(self.bucket_rounds),
            "retraces": retrace_report({"eval": engine._eval_jit,
                                        "lockstep": engine._vec_jit}),
        }


class EvalEngine:
    """Batched acquisition evaluation plane behind every MSO strategy."""

    def __init__(self, acq_fn: AcqStateFn):
        self.acq_fn = acq_fn
        self.stats = EngineStats()

        def _neg_value_and_grad(state, X):
            f = -acq_fn(state, X)
            g = jax.grad(lambda Z: -jnp.sum(acq_fn(state, Z)))(X)
            return f, g

        self._eval_jit = CountingJit(_neg_value_and_grad)

        def _run_lockstep(state, x0, lower, upper, opts: LbfgsbOptions,
                          plan: EvalPlan):
            fun = self.device_fun(state, plan)
            return lbfgsb_minimize(fun, x0, lower, upper, opts)

        self._vec_jit = CountingJit(_run_lockstep, static_argnums=(4, 5))
        # obs device-completion timing; passthrough with tracing off
        self._eval_jit = obs.ProgramTimer(self._eval_jit,
                                          "engine.program.eval")
        self._vec_jit = obs.ProgramTimer(self._vec_jit,
                                         "engine.program.lockstep")

    @property
    def n_compiles(self) -> int:
        """Total XLA traces issued by this engine (all entry points)."""
        return self._eval_jit.n_compiles + self._vec_jit.n_compiles

    # ------------------------------------------------------------- device
    def device_fun(self, state, plan: EvalPlan):
        """Batched ``(B, q·D) → ((B,), (B, q·D))`` evaluation for the
        lockstep solver; traced inside the solver's program (also consumed
        by the fused ask program in ``engine/ask.py``)."""
        acq_fn = self.acq_fn

        def fun_batched(X: Array) -> Tuple[Array, Array]:
            Xq = X.reshape((X.shape[0],) + plan.point_shape)
            f = -acq_fn(state, Xq)
            g = jax.grad(lambda Z: -jnp.sum(
                acq_fn(state, Z.reshape((Z.shape[0],) + plan.point_shape))
            ))(X)
            return f, g

        return fun_batched

    def fleet_device_fun(self, states, plan: EvalPlan):
        """Batched ``(S, B, q·D) → ((S, B), (S, B, q·D))`` evaluation for
        the fleet's leading-batch lockstep solver.

        ``states`` is the per-slot acquisition state stacked along a
        leading study axis (every pytree leaf leads with S); row s of the
        evaluation batch is scored against study s's state.  Consumed by
        the fleet ask programs in ``engine/fleet.py``.
        """
        acq_fn = self.acq_fn

        def acq_all(states_, X):
            Xq = X.reshape(X.shape[:2] + plan.point_shape)
            return jax.vmap(acq_fn)(states_, Xq)          # (S, B)

        def fun_batched(X: Array) -> Tuple[Array, Array]:
            f = -acq_all(states, X)
            g = jax.grad(lambda Z: -jnp.sum(acq_all(states, Z)))(X)
            return f, g

        return fun_batched

    def run_lockstep(self, state, x0: Array, lower: Array, upper: Array,
                     opts: LbfgsbOptions, plan: EvalPlan) -> LbfgsbResult:
        """dbe_vec: the whole multi-start solve as ONE jitted program
        (zero per-iteration host syncs; masked lockstep active set)."""
        res = self._vec_jit(state, x0, lower, upper, opts, plan)
        self.record_lockstep_economy(x0.shape[0], res.rounds, res.n_evals)
        return res

    def record_lockstep_economy(self, B: int, rounds, n_evals) -> None:
        """Surface a device lockstep solve's evaluation economy into
        EngineStats so the strategy is tracked like the host-facing ones:
        every device round evaluates the full (frozen rows included)
        B-batch, so rounds·B − Σ active-evals is the padding analogue.
        Called by :meth:`run_lockstep` and the fused ask pipeline."""
        rounds = int(rounds)
        evals = int(np.sum(np.asarray(n_evals)))
        self.stats.n_rounds += rounds
        self.stats.n_points += evals
        self.stats.n_padded += rounds * B - evals
        self.stats.bucket_rounds[B] = \
            self.stats.bucket_rounds.get(B, 0) + rounds

    def record_refit_fallback(self) -> None:
        """An incremental (rank-one) refit failed its Schur-complement
        soundness check and was demoted to a full MAP refit — the
        exactness guardrail firing, tracked like evaluation economy.
        Called by ``AskEngine.suggest`` and the fleet's step loop."""
        self.stats.n_refit_fallbacks += 1

    # --------------------------------------------------------------- host
    def evaluator(self, state, plan: EvalPlan) -> BatchEvalFn:
        """numpy-facing batched ``(-acq, -∇acq)`` evaluator for the scipy
        coroutine strategies.

        Pads each request up to ``plan.bucket_for(k)`` (repeating the last
        row — values at real points are unaffected), evaluates once on
        device, and slices the first k results back out.
        """

        def batch_eval(X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
            k = X.shape[0]
            b = plan.bucket_for(k)
            if b > k:
                Xp = np.concatenate([X, np.repeat(X[-1:], b - k, 0)], 0)
            else:
                Xp = X
            Xd = jnp.asarray(Xp).reshape((b,) + plan.point_shape)
            f, g = self._eval_jit(state, Xd)
            self.stats.n_rounds += 1
            self.stats.n_points += k
            self.stats.n_padded += b - k
            self.stats.bucket_rounds[b] = \
                self.stats.bucket_rounds.get(b, 0) + 1
            return (np.asarray(f)[:k],
                    np.asarray(g).reshape(b, -1)[:k])

        return batch_eval

    # ------------------------------------------------------------- values
    def values(self, state, X, plan: EvalPlan = None) -> np.ndarray:
        """Acquisition values (maximization scale) at ``(k, ...)`` points.
        Scoring entry for callers that only need values (re-ranking a
        candidate pool, inspecting a surface); shares the jitted primitive
        (and its cache) with the optimizers."""
        Xd = jnp.asarray(X)
        if plan is not None:
            Xd = Xd.reshape((Xd.shape[0],) + plan.point_shape)
        f, _ = self._eval_jit(state, Xd)
        return -np.asarray(f)

    def stats_snapshot(self) -> Dict[str, Any]:
        return self.stats.snapshot(self)


# Casual callers (tests, examples, one-off maximize_acqf invocations) get
# a process-wide engine per acquisition function, restoring the seed
# repo's module-level-jit compile economy without threading engine objects
# through every call site.
_DEFAULT_ENGINES: "weakref.WeakKeyDictionary[Callable, EvalEngine]" = \
    weakref.WeakKeyDictionary()


def default_engine(acq_fn: AcqStateFn) -> EvalEngine:
    eng = _DEFAULT_ENGINES.get(acq_fn)
    if eng is None:
        eng = EvalEngine(acq_fn)
        try:
            _DEFAULT_ENGINES[acq_fn] = eng
        except TypeError:          # non-weakref-able callables: no cache
            pass
    return eng
