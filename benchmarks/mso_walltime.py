"""Paper §5 wall-clock claim + §4 cost model — MSO micro-benchmark.

Fixes a fitted GP (n training points) and times ONE acquisition
optimization (B=10 restarts, LogEI) per strategy.  Validates:

* C5 (cost model): batched eval cost O(B(n²+nD)) dominates the O(BmD) QN
  update when n ≫ m — measured as eval-time share.
* the 1.5×(vs SEQ.) / 1.1×(vs C-BE) wall-clock speedups of D-BE, and the
  beyond-paper D-BE-vectorized device-resident variant.
"""
import jax

jax.config.update("jax_enable_x64", True)

import time                       # noqa: E402

import jax.numpy as jnp           # noqa: E402
import numpy as np                # noqa: E402

from repro.core.acquisition import logei_acq          # noqa: E402
from repro.core.mso import MsoOptions, maximize_acqf  # noqa: E402
from repro.gp.fit import fit_gp, standardize          # noqa: E402


def setup_gp(n: int, D: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, D))
    # high-frequency target -> short fitted lengthscales -> a wiggly,
    # multi-modal LogEI surface that makes the QN solvers actually work
    y = np.sin(8 * X).sum(1) + 0.3 * np.cos(13 * X[:, 0]) \
        + 0.05 * rng.standard_normal(n)
    y_std, _, _ = standardize(jnp.asarray(-y))
    gp = fit_gp(jnp.asarray(X), y_std, n_restarts=2, pad_bucket=32)
    return gp, float(jnp.max(y_std))


def bench(n: int, D: int, B: int = 10, reps: int = 5, seed: int = 0):
    gp, best = setup_gp(n, D, seed)
    state = (gp, jnp.asarray(best))
    rng = np.random.default_rng(seed + 1)
    opts = MsoOptions(m=10, maxiter=200, pgtol=1e-5)
    rows = []
    for strategy in ("seq", "cbe", "dbe", "dbe_vec"):
        walls, iters, rounds = [], [], []
        for r in range(reps + 1):
            x0 = rng.uniform(0, 1, (B, D))
            res = maximize_acqf(logei_acq, x0, 0.0, 1.0, acq_state=state,
                                strategy=strategy, options=opts)
            if r == 0:
                continue          # warm-up (jit compile)
            walls.append(res.wall_time)
            iters.append(float(np.median(res.n_iters)))
            rounds.append(res.n_rounds)
        rows.append({
            "n": n, "D": D, "B": B, "strategy": strategy,
            "wall_ms": 1e3 * float(np.median(walls)),
            "med_iters": float(np.median(iters)),
            "rounds": float(np.median(rounds)),
        })
    base = rows[0]["wall_ms"]
    cbe = rows[1]["wall_ms"]
    for r in rows:
        r["speedup_vs_seq"] = base / r["wall_ms"]
        r["speedup_vs_cbe"] = cbe / r["wall_ms"]
        print(f"mso,n={r['n']},D={r['D']},{r['strategy']},"
              f"wall={r['wall_ms']:.1f}ms,iters={r['med_iters']:.1f},"
              f"rounds={r['rounds']:.0f},"
              f"vs_seq={r['speedup_vs_seq']:.2f}x", flush=True)
    return rows


def main(full=False):
    cases = [(64, 5), (192, 5), (192, 20)] if not full else \
        [(64, 5), (128, 10), (192, 20), (288, 40)]
    out = []
    for n, D in cases:
        out.extend(bench(n, D))
    return out


if __name__ == "__main__":
    main()
