"""Roofline analysis from the dry-run JSONs (see launch/dryrun.py).

Per (arch × shape × mesh):
  compute    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
  memory     = HLO_bytes_per_device / HBM_bw               [s]
  collective = collective_bytes_per_device / link_bw       [s]
  MODEL_FLOPS (analytic) = 6·N·D_tokens (train) / 2·N·D (prefill)
                         / 2·N·B (decode), N = active params
  usefulness = MODEL_FLOPS / (HLO_FLOPs_per_device × chips)

Emits the EXPERIMENTS.md §Roofline markdown table + per-cell bottleneck
lever notes.  Run:  PYTHONPATH=src python -m benchmarks.roofline \
    --dir results/dryrun --markdown
"""
import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.launch.mesh import PEAK_FLOPS_BF16
from repro.models.config import param_counts

LEVERS = {
    "compute": "raise MXU utilization: larger microbatch / fused matmuls "
               "/ bf16 everywhere",
    "memory": "cut HBM traffic: tighter remat policy, fused attention "
              "(Pallas), smaller collective staging buffers",
    "collective": "reshard: fewer TP all-reduces (2D sharding), overlap "
                  "via microbatch pipelining, bf16 collectives",
}


def model_flops(arch: str, shape: str, rec: dict) -> float:
    cfg = get_config(arch)
    n_active = param_counts(cfg)["active"]
    if shape == "train_4k":
        tokens = 256 * 4096
        return 6.0 * n_active * tokens
    if shape == "prefill_32k":
        return 2.0 * n_active * 32 * 32768
    if shape == "decode_32k":
        return 2.0 * n_active * 128
    if shape == "long_500k":
        return 2.0 * n_active * 1
    raise KeyError(shape)


def load(dirname: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def analyze(rec: dict) -> dict:
    out = dict(rec)
    if rec.get("status") != "ok":
        return out
    mf = model_flops(rec["arch"].replace("_", "-", 1)
                     if False else rec["arch"], rec["shape"], rec)
    total_hlo = rec["flops_per_device"] * rec["n_chips"]
    out["model_flops"] = mf
    out["usefulness"] = mf / total_hlo if total_hlo else 0.0
    # roofline fraction: useful-FLOPs time vs the bounding term
    t_bound = max(rec["t_compute"], rec["t_memory"], rec["t_collective"])
    t_useful = (mf / rec["n_chips"]) / PEAK_FLOPS_BF16
    out["roofline_fraction"] = t_useful / t_bound if t_bound else 0.0
    out["lever"] = LEVERS[rec["bottleneck"]]
    return out


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | useful/HLO | roofline frac | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — "
                         f"| — | — | skipped: {r['skip_reason'][:42]} | — "
                         f"| — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — "
                         f"| — | — | ERROR | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | **{r['bottleneck']}** "
            f"| {r['usefulness']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {'y' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = [analyze(r) for r in load(args.dir)]
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in rows:
            if r.get("status") == "ok":
                print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                      f"bottleneck={r['bottleneck']},"
                      f"frac={r['roofline_fraction']:.3f},"
                      f"useful={r['usefulness']:.2f},"
                      f"fits={r['fits_hbm']}")
            else:
                print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                      f"{r['status']}")
    return rows


if __name__ == "__main__":
    main()
