"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, pattern 1 attn : 2
recurrent, window 2048.  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, lru_width=4096.  [arXiv:2402.19427; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    attn_every=3, window=2048, lru_width=4096, conv_width=4,
    norm="rmsnorm", activation="geglu",
    sub_quadratic=True,
)
