"""COCO/BBOB-style benchmark objectives (numpy, black-box side).

The paper's §5 benchmarks: Sphere (f1), Attractive Sector (f6), Step
Ellipsoidal (f7), Rastrigin (rotated, f15) on [-5, 5]^D, plus Rosenbrock for
the off-diagonal-artifact study (§3, Figures 1–5).  Implemented to the BBOB
definitions (T_osz / T_asy / Λ^α / random rotations), seeded per instance.

These are *black-box* objectives: BO only sees f(x); no JAX needed here.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

DOMAIN = (-5.0, 5.0)


def _rotation(rng: np.random.Generator, d: int) -> np.ndarray:
    q, r = np.linalg.qr(rng.standard_normal((d, d)))
    return q * np.sign(np.diag(r))


def _t_osz(x: np.ndarray) -> np.ndarray:
    xhat = np.where(x != 0, np.log(np.abs(x) + 1e-300), 0.0)
    c1 = np.where(x > 0, 10.0, 5.5)
    c2 = np.where(x > 0, 7.9, 3.1)
    return np.sign(x) * np.exp(
        xhat + 0.049 * (np.sin(c1 * xhat) + np.sin(c2 * xhat)))


def _t_asy(x: np.ndarray, beta: float) -> np.ndarray:
    d = x.shape[-1]
    i = np.arange(d) / max(d - 1, 1)
    expo = 1.0 + beta * i * np.sqrt(np.maximum(x, 0.0))
    return np.where(x > 0, np.power(np.maximum(x, 0.0), expo), x)


def _lam(alpha: float, d: int) -> np.ndarray:
    i = np.arange(d) / max(d - 1, 1)
    return np.power(alpha, 0.5 * i)


class BBOBFunction:
    """Callable objective with instance-seeded optimum/rotations."""

    def __init__(self, name: str, dim: int, seed: int = 1):
        self.name = name
        self.dim = dim
        rng = np.random.default_rng(seed * 1000003 + dim)
        self.x_opt = rng.uniform(-4.0, 4.0, dim)
        self.f_opt = 0.0
        self._R = _rotation(rng, dim)
        self._Q = _rotation(rng, dim)
        self._fn = _FUNCS[name]

    def __call__(self, x: np.ndarray) -> float:
        x = np.asarray(x, np.float64)
        return float(self._fn(self, x) + self.f_opt)

    @property
    def bounds(self):
        return DOMAIN


def _sphere(self: BBOBFunction, x):
    z = x - self.x_opt
    return np.sum(z * z)


def _rastrigin(self: BBOBFunction, x):
    """BBOB f15 (rotated Rastrigin)."""
    z = self._R @ (x - self.x_opt)
    z = _t_asy(_t_osz(z), 0.2)
    z = self._R @ (_lam(10.0, self.dim) * (self._Q @ z))
    return 10.0 * (self.dim - np.sum(np.cos(2 * np.pi * z))) + np.sum(z * z)


def _attractive_sector(self: BBOBFunction, x):
    """BBOB f6."""
    z = self._Q @ (_lam(10.0, self.dim) * (self._R @ (x - self.x_opt)))
    s = np.where(z * self.x_opt > 0, 100.0, 1.0)
    val = np.sum((s * z) ** 2)
    return float(_t_osz(np.asarray([val]))[0]) ** 0.9


def _step_ellipsoidal(self: BBOBFunction, x):
    """BBOB f7."""
    zhat = _lam(10.0, self.dim) * (self._R @ (x - self.x_opt))
    ztilde = np.where(np.abs(zhat) > 0.5,
                      np.floor(0.5 + zhat),
                      np.floor(0.5 + 10.0 * zhat) / 10.0)
    z = self._Q @ ztilde
    i = np.arange(self.dim) / max(self.dim - 1, 1)
    val = np.sum(np.power(10.0, 2.0 * i) * z * z)
    return 0.1 * max(np.abs(zhat[0]) / 1e4, val)


def _rosenbrock(self: BBOBFunction, x):
    """Plain Rosenbrock (the §3 artifact-study objective; optimum at 1)."""
    return float(np.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2
                        + (1.0 - x[:-1]) ** 2))


_FUNCS: Dict[str, Callable] = {
    "sphere": _sphere,
    "rastrigin": _rastrigin,
    "attractive_sector": _attractive_sector,
    "step_ellipsoidal": _step_ellipsoidal,
    "rosenbrock": _rosenbrock,
}

OBJECTIVES = tuple(_FUNCS)


def make_objective(name: str, dim: int, seed: int = 1) -> BBOBFunction:
    if name not in _FUNCS:
        raise KeyError(f"unknown objective {name!r}; have {OBJECTIVES}")
    return BBOBFunction(name, dim, seed)
