"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment note, ``[audio]`` entries specify the transformer
BACKBONE only: ``input_specs()`` feeds precomputed frame embeddings
(B, S_enc, d_model) — the conv frontend is a stub.  Positions are
sinusoidal (computed on the fly).  The decoder carries a self-attention KV
cache plus encoder cross-attention K/V computed once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import Boxed, box, constrain, is_boxed
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array


def _sinusoidal(positions: Array, d: int, dtype) -> Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree.map(lambda b: Boxed(b.value, (None,) + b.axes),
                        stacked, is_leaf=is_boxed)


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": L.init_norm(cfg, dtype),
            "attn": L.init_attention(k1, cfg, dtype),
            "mlp_norm": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(k2, cfg, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_norm": L.init_norm(cfg, dtype),
            "self_attn": L.init_attention(k1, cfg, dtype),
            "cross_norm": L.init_norm(cfg, dtype),
            "cross_attn": L.init_attention(k2, cfg, dtype),
            "mlp_norm": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(k3, cfg, dtype),
        }

    return {
        "embed": L.init_embedding(k_emb, cfg, dtype),
        "enc": _stack_init(enc_layer, k_enc, cfg.n_enc_layers),
        "dec": _stack_init(dec_layer, k_dec, cfg.n_dec_layers),
        "enc_norm": L.init_norm(cfg, dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }


def _cross_attend(p, cfg, x, enc_k, enc_v, enc_pos):
    """Decoder→encoder attention with precomputed encoder K/V."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].value)
    q = constrain(q, "batch", None, "heads", None)
    q_pos = jnp.zeros((B, S), jnp.int32)  # non-causal: positions unused
    out = L.attention_xla(q, enc_k, enc_v, causal=False, window=0,
                          q_pos=q_pos, kv_pos=enc_pos,
                          chunk=cfg.attn_chunk)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].value)
    return constrain(y, "batch", None, None)


def _enc_kv(p, enc_out):
    k = jnp.einsum("bsd,dkh->bskh", enc_out, p["wk"].value)
    v = jnp.einsum("bsd,dkh->bskh", enc_out, p["wv"].value)
    return k, v


def encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """frames: (B, S_enc, D) stub embeddings → encoder hidden states."""
    B, S, D = frames.shape
    dt = jnp.dtype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = frames.astype(dt) + _sinusoidal(pos, D, dt)
    x = constrain(x, "batch", None, None)

    def body(h, p_layer):
        a = L.apply_norm(p_layer["attn_norm"], h, cfg.norm)
        a, _ = L.apply_attention(p_layer["attn"], cfg, a, pos, causal=False)
        h = h + a
        m = L.apply_norm(p_layer["mlp_norm"], h, cfg.norm)
        return h + L.apply_mlp(p_layer["mlp"], cfg, m), None

    x, _ = lax.scan(jax.checkpoint(body) if cfg.remat != "none" else body,
                    x, params["enc"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def decode_train(params, cfg: ModelConfig, enc_out: Array,
                 tokens: Array) -> Array:
    """Teacher-forced decoder pass → final hidden (B, S_dec, D)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params["embed"], tokens)
    x = x + _sinusoidal(pos, cfg.d_model, x.dtype)
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
        (B, enc_out.shape[1]))

    def body(h, p_layer):
        a = L.apply_norm(p_layer["self_norm"], h, cfg.norm)
        a, _ = L.apply_attention(p_layer["self_attn"], cfg, a, pos,
                                 causal=True)
        h = h + a
        c = L.apply_norm(p_layer["cross_norm"], h, cfg.norm)
        ek, ev = _enc_kv(p_layer["cross_attn"], enc_out)
        h = h + _cross_attend(p_layer["cross_attn"], cfg, c, ek, ev, enc_pos)
        m = L.apply_norm(p_layer["mlp_norm"], h, cfg.norm)
        return h + L.apply_mlp(p_layer["mlp"], cfg, m), None

    x, _ = lax.scan(jax.checkpoint(body) if cfg.remat != "none" else body,
                    x, params["dec"])
    return L.apply_norm(params["final_norm"], x, cfg.norm)


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, Array]) -> Array:
    from repro.models.lm import cross_entropy
    enc_out = encode(params, cfg, batch["frames"])
    hidden = decode_train(params, cfg, enc_out, batch["tokens"])
    return cross_entropy(params, cfg, hidden, batch["targets"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ModelConfig, enc_out: Array, batch: int,
               max_len: int) -> Dict[str, Any]:
    """Decoder cache: per-layer self-attn KV + precomputed cross K/V."""
    dtype = jnp.dtype(cfg.dtype)

    def rep(tree, n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)

    self_cache = rep(L.init_attn_cache(cfg, batch, max_len, dtype),
                     cfg.n_dec_layers)
    cross = _cross_all(params, cfg, enc_out)
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
        (batch, enc_out.shape[1]))
    return {"self": self_cache, "cross": cross, "enc_pos": enc_pos}


def _cross_all(params, cfg, enc_out):
    def body(_, p_layer):
        k, v = _enc_kv(p_layer["cross_attn"], enc_out)
        return None, {"k": k, "v": v}
    _, cross = lax.scan(body, None, params["dec"])
    return cross


def decode_step(params, cfg: ModelConfig, tokens: Array, cache,
                position) -> Tuple[Array, Any]:
    """One decoder step.  tokens: (B, 1) → (logits (B, V), new cache)."""
    B = tokens.shape[0]
    pos = jnp.broadcast_to(
        jnp.asarray(position, jnp.int32)[None, None], (B, 1))
    x = L.embed_tokens(params["embed"], tokens)
    x = x + _sinusoidal(pos, cfg.d_model, x.dtype)

    def body(carry, inp):
        h, ck, cv, cpos = carry
        p_layer, cross_c, li = inp
        self_c = {
            "k": lax.dynamic_index_in_dim(ck, li, 0, keepdims=False),
            "v": lax.dynamic_index_in_dim(cv, li, 0, keepdims=False),
            "pos": lax.dynamic_index_in_dim(cpos, li, 0, keepdims=False),
        }
        a = L.apply_norm(p_layer["self_norm"], h, cfg.norm)
        a, nc = L.apply_attention(p_layer["self_attn"], cfg, a, pos,
                                  causal=True, cache=self_c,
                                  cache_index=position)
        h = h + a
        c = L.apply_norm(p_layer["cross_norm"], h, cfg.norm)
        h = h + _cross_attend(p_layer["cross_attn"], cfg, c,
                              cross_c["k"], cross_c["v"],
                              cache["enc_pos"])
        m = L.apply_norm(p_layer["mlp_norm"], h, cfg.norm)
        h = h + L.apply_mlp(p_layer["mlp"], cfg, m)
        ck = lax.dynamic_update_index_in_dim(ck, nc["k"], li, 0)
        cv = lax.dynamic_update_index_in_dim(cv, nc["v"], li, 0)
        cpos = lax.dynamic_update_index_in_dim(cpos, nc["pos"], li, 0)
        return (h, ck, cv, cpos), None

    sc = cache["self"]
    n_layers = sc["pos"].shape[0]
    (x, ck, cv, cpos), _ = lax.scan(
        body, (x, sc["k"], sc["v"], sc["pos"]),
        (params["dec"], cache["cross"], jnp.arange(n_layers)))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.lm_logits(params["embed"], cfg, x)[:, 0, :]
    new_cache = {"self": {"k": ck, "v": cv, "pos": cpos},
                 "cross": cache["cross"], "enc_pos": cache["enc_pos"]}
    return logits, new_cache
