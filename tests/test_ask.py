"""Fused device-resident ask() tests: incremental-refit exactness (vs a
from-scratch fit), fused-vs-host trajectory equality, compile economy,
and the controller's failure reporting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bo.sampler import GPSampler
from repro.bo.space import BoxSpace
from repro.core.acquisition import logei_acq
from repro.core.mso import MsoOptions
from repro.engine import AskConfig, AskEngine, EvalEngine
from repro.gp.fit import _FAR, incremental_update, standardize_masked
from repro.gp.gpr import GPState, fit_gram, predict
from repro.gp.kernels import init_params


def _sphere(x):
    return float(np.sum((x - 0.4) ** 2))


def _sampler(fused, *, seed=3, refit_interval=8, warm_start=True,
             pad=16, backend="auto"):
    return GPSampler(BoxSpace.cube(3, -1.0, 1.0), strategy="dbe_vec",
                     seed=seed, n_startup_trials=5, n_restarts=6,
                     fused=fused, refit_interval=refit_interval,
                     warm_start=warm_start, pad_multiple=pad,
                     posterior_backend=backend,
                     mso_options=MsoOptions(maxiter=60, pgtol=1e-2))


# ------------------------------------------------------ incremental refit
def test_incremental_update_matches_from_scratch():
    """Rank-one Cholesky/K⁻¹ append == full refactorization to ≤1e-8,
    growing one observation at a time through a padded buffer."""
    rng = np.random.default_rng(0)
    b, D, n0 = 24, 3, 7
    p = init_params(D)
    X = rng.uniform(0, 1, (b, D))
    yv = np.sin(4 * X).sum(1)
    x = jnp.full((b, D), _FAR) + jnp.arange(b, dtype=jnp.float64)[:, None]

    def scratch(n):
        """From-scratch padded factorization at fixed θ (gram + mask)."""
        from jax.scipy.linalg import cho_solve
        from repro.gp.kernels import gram
        v = (jnp.arange(b) < n).astype(jnp.float64)
        K = gram(x, p, "matern52")
        K = K * (v[:, None] * v[None, :]) + jnp.diag(1.0 - v)
        L = jnp.linalg.cholesky(K)
        ys, _, _ = standardize_masked(y * v, jnp.arange(b) < n)
        return L, cho_solve((L, True), ys), cho_solve((L, True), jnp.eye(b))

    x = x.at[:n0].set(jnp.asarray(X[:n0]))
    y = jnp.zeros(b).at[:n0].set(jnp.asarray(yv[:n0]))
    chol, _, kinv = scratch(n0)
    for n in range(n0 + 1, b + 1):
        x = x.at[n - 1].set(jnp.asarray(X[n - 1]))
        y = y.at[n - 1].set(float(yv[n - 1]))
        ys, _, _ = standardize_masked(y, jnp.arange(b) < n)
        chol, alpha, kinv, ok = incremental_update(
            x, ys, jnp.asarray(n), p, chol, kinv)
        assert bool(ok), n
        L_ref, a_ref, k_ref = scratch(n)
        np.testing.assert_allclose(np.asarray(chol), np.asarray(L_ref),
                                   atol=1e-8)
        np.testing.assert_allclose(np.asarray(alpha), np.asarray(a_ref),
                                   atol=1e-8)
        np.testing.assert_allclose(np.asarray(kinv), np.asarray(k_ref),
                                   atol=1e-8)


def test_incremental_ask_posterior_matches_full_across_buckets():
    """Driving the AskEngine across bucket boundaries, every incremental
    trial's GP state reproduces a from-scratch fit (same θ) to ≤1e-8."""
    rng = np.random.default_rng(1)
    D = 3
    cfg = AskConfig(dim=D, n_restarts=4, pad_bucket=8, refit_interval=6,
                    backend="pallas_interpret")   # exercises the kinv path
    ask = AskEngine(EvalEngine(logei_acq), cfg)
    for i in range(5):
        xi = rng.uniform(0, 1, D)
        ask.observe(xi, _sphere(xi))

    checked = 0
    for t in range(16):                       # crosses 8- and 16-buckets
        key = jax.random.fold_in(jax.random.PRNGKey(0), t)
        bx, info = ask.suggest(key, fit_seed=t)
        if info.kind == "incremental":
            gp = ask.gp_state()
            n = ask.n_obs
            ref = fit_gram(gp.x_train[:n], gp.y_train[:n], gp.params)
            Xq = jnp.asarray(rng.uniform(0, 1, (9, D)))
            m_inc, v_inc = predict(gp, Xq)
            m_ref, v_ref = predict(ref, Xq)
            np.testing.assert_allclose(np.asarray(m_inc),
                                       np.asarray(m_ref), atol=1e-8)
            np.testing.assert_allclose(np.asarray(v_inc),
                                       np.asarray(v_ref), atol=1e-8)
            checked += 1
        xn = np.clip(bx, 0, 1)
        ask.observe(xn, _sphere(xn))
    assert checked >= 8                       # incremental trials dominated
    assert ask.n_full_refits >= 3             # boundary + interval refits


# ------------------------------------------------- fused == host pipeline
def test_fused_reproduces_unfused_trajectory_bitwise():
    """With incremental updates disabled (refit_interval=1, no warm
    start), the one-program fused ask() must reproduce the host dbe_vec
    pipeline's suggestions bit-for-bit across a bucket boundary."""
    n_trials = 18
    sa, sb = _sampler(False, refit_interval=1, warm_start=False), \
        _sampler(True, refit_interval=1, warm_start=False)
    for i in range(n_trials):
        ta, tb = sa.ask(), sb.ask()
        np.testing.assert_array_equal(ta.x, tb.x, err_msg=f"trial {i}")
        sa.tell(ta.trial_id, _sphere(ta.x))
        sb.tell(tb.trial_id, _sphere(tb.x))
    assert sb._ask.n_incremental == 0
    assert sb._ask.n_full_refits == n_trials - 5


def test_fused_default_quality_with_incremental():
    """Default fused config (incremental updates on, warm-started refits)
    still optimizes: sanity guard that speed didn't cost convergence."""
    s = _sampler(True)
    best = s.optimize(_sphere, 24)
    assert best.y < 0.25, best
    snap = s._ask.stats_snapshot()
    assert snap["n_incremental"] > snap["n_full_refits"]
    assert snap["n_fallbacks"] == 0


def test_fused_compile_counts_stay_o_buckets():
    """30 trials crossing two bucket boundaries: at most one full + one
    incremental trace per GP size bucket — O(#buckets), not O(trials)."""
    s = _sampler(True, pad=8)
    s.optimize(_sphere, 30)
    ask = s._ask
    n_buckets = 4                   # suggests span n=5..29 → pads 8..32
    assert ask.bucket == 32
    snap = ask.stats_snapshot()
    assert snap["n_full_compiles"] <= n_buckets
    assert snap["n_incr_compiles"] <= n_buckets
    assert snap["n_full_refits"] + snap["n_incremental"] == 30 - 5


def test_fused_handles_out_of_order_tell():
    """Two pending asks completed in reverse order must not duplicate or
    drop observations in the fused GP (sync is keyed by trial id)."""
    s = _sampler(True, seed=9)
    for _ in range(5):
        t = s.ask()
        s.tell(t.trial_id, _sphere(t.x))
    t1, t2 = s.ask(), s.ask()              # two pending suggestions
    s.tell(t2.trial_id, _sphere(t2.x))     # ...completed out of order
    s.tell(t1.trial_id, _sphere(t1.x))
    for _ in range(3):
        t = s.ask()
        s.tell(t.trial_id, _sphere(t.x))
    s.ask()                                # final sync into the ask GP
    ask = s._ask
    assert ask.n_obs == 10                 # 5 startup + 2 + 3, no dupes
    done_y = sorted(t.y for t in s.trials if t.state == "complete")
    gp_y = sorted(np.asarray(ask._y[:ask.n_obs]).tolist())
    np.testing.assert_allclose(gp_y, done_y, atol=0)


def test_fused_requires_dbe_vec():
    with pytest.raises(ValueError):
        GPSampler(BoxSpace.cube(2, 0.0, 1.0), strategy="dbe", fused=True)


# ------------------------------------------------- controller error paths
def test_best_without_completed_trials_raises_clear_error():
    s = _sampler(True)
    with pytest.raises(RuntimeError, match="no completed trials"):
        s.best()
    t = s.ask()
    s.tell(t.trial_id, 0.0, failed=True, error="ValueError: boom")
    with pytest.raises(RuntimeError, match="boom"):
        s.best()


def test_optimize_preserves_failure_reason():
    s = _sampler(True)

    def exploding(x):
        raise ValueError("objective exploded at x=...")

    with pytest.raises(RuntimeError, match="objective exploded"):
        s.optimize(exploding, 3)
    assert all(t.state == "failed" for t in s.trials)
    assert all("ValueError" in t.error for t in s.trials)
