"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
SPMD-partitions, and compiles on the production meshes, and extract the
roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --sweep --out results/dryrun

Each cell writes one JSON with memory_analysis, cost_analysis, per-type
collective bytes (parsed from the compiled per-device HLO), and timing.
The sweep is resumable: existing JSONs are skipped.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: jax locks the device count on first
#   use.  These two lines are the first executable statements of the module
#   (the docstring above compiles to a constant; no __future__ import here
#   precisely so these lines can run before anything else).

import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import (HBM_BYTES, HBM_BW, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16, make_production_mesh,
                               use_mesh)
from repro.launch.shapes import SHAPES, build_cell, cell_supported

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(" + "|".join(COLLECTIVE_OPS)
    + r")(?:-(?:start|done))?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-type result bytes in the per-device HLO module.

    '-start' ops are counted, their '-done' twins skipped (same tensor)."""
    out = {op: {"bytes": 0, "count": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        out[m.group(2)]["bytes"] += _type_bytes(m.group(1))
        out[m.group(2)]["count"] += 1
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: Optional[dict] = None) -> dict:
    """Lower + compile one cell; return the roofline record."""
    # per-arch baseline distribution defaults (documented in DESIGN.md §6):
    # dbrx-132b's 264 GB of bf16 params exceed TP-16 HBM → FSDP.
    arch_defaults = {"dbrx-132b": {"fsdp": True}}
    # normalize: ARCH_IDS use underscores, defaults use canonical dashes
    norm = arch.replace("_", "-")
    merged = dict(arch_defaults.get(arch, arch_defaults.get(norm, {})))
    merged.update(overrides or {})
    grad_accum = merged.pop("grad_accum", None)
    opt_kw = {k: merged.pop(k) for k in
              ("grad_compression", "zero1", "shard_grads") if k in merged}
    opt_cfg = None
    if opt_kw:
        from repro.train.optim import OptimConfig
        opt_cfg = OptimConfig(**opt_kw)
    cfg = get_config(arch)
    if merged:
        cfg = cfg.replace(**merged)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "family": cfg.family, "status": "skipped", "skip_reason": why,
    }
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    with use_mesh(mesh):
        step, args, shards, out_shards, donate = build_cell(
            cfg, shape, mesh, grad_accum=grad_accum, opt_cfg=opt_cfg)
        jitted = jax.jit(step, in_shardings=shards,
                         out_shardings=out_shards,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_rec[k] = int(getattr(mem, k, 0) or 0)
    # live bytes per device: args + temps (donated outputs alias args)
    live = mem_rec["argument_size_in_bytes"] + mem_rec["temp_size_in_bytes"]

    cost = compiled.cost_analysis() or {}

    # trip-count-aware analysis (XLA's cost_analysis counts while-loop
    # bodies ONCE — useless under scan-over-layers; launch/hlo_cost.py)
    from repro.launch.hlo_cost import analyze as hlo_analyze
    hlo = hlo_analyze(compiled.as_text())
    flops = float(hlo["flops"])
    bytes_accessed = float(hlo["bytes"])
    coll = hlo["collectives"]
    coll_total = sum(v["bytes"] for v in coll.values())

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "live_bytes_per_device": live,
        "fits_hbm": bool(live <= HBM_BYTES),
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "collectives": coll,
        "collective_bytes_per_device": coll_total,
        # roofline terms (seconds, per the assignment formulas)
        "t_compute": flops / PEAK_FLOPS_BF16,
        "t_memory": bytes_accessed / HBM_BW,
        "t_collective": coll_total / ICI_BW_PER_LINK,
    })
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--sweep", action="store_true",
                    help="run every remaining (arch × shape) for --mesh")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. remat=dots)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)

    def one(arch, shape_name):
        tag = f"{arch.replace('.', '_')}__{shape_name}__{args.mesh}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and args.sweep:
            print(f"[skip existing] {tag}")
            return
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = run_cell(arch, shape_name, args.mesh, overrides or None)
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        jax.clear_caches()   # bound sweep RSS: drop compiled executables
        status = rec["status"]
        extra = ""
        if status == "ok":
            gib = rec["live_bytes_per_device"] / 2**30
            extra = (f" compile={rec['compile_s']}s live={gib:.2f}GiB "
                     f"fits={rec['fits_hbm']} bottleneck={rec['bottleneck']}")
        print(f"[done] {tag}: {status}{extra}", flush=True)

    if args.sweep:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                one(arch, shape_name)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --sweep")
        one(args.arch, args.shape)


if __name__ == "__main__":
    main()
