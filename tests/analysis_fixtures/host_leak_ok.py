"""Fixture: trace-disciplined twin of ``host_leak_bad`` — shape/config
branches, lax control flow, device-side reductions.  Zero
``host-leak-into-trace`` findings."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branch_on_shape(x, y):
    # shape/ndim branches are static facts, resolved at trace time
    if x.ndim == 2:
        return y
    return -y


@functools.partial(jax.jit, static_argnums=(1,))
def branch_on_static(x, mode):
    if mode == "sum":
        return jnp.sum(x)
    return jnp.max(x)


@jax.jit
def data_dependent_on_device(x, y):
    return jnp.where(x > 0, y, -y)


@jax.jit
def optional_arg(x, scale=None):
    if scale is None:
        return x
    return x * scale
