"""Paper §5 wall-clock claim + §4 cost model — MSO micro-benchmark.

Fixes a fitted GP (n training points) and times ONE acquisition
optimization (B restarts, LogEI) per strategy, all four strategies running
through the shared evaluation engine.  Validates:

* C5 (cost model): batched eval cost O(B(n²+nD)) dominates the O(BmD) QN
  update when n ≫ m — measured as eval-time share.
* the 1.5×(vs SEQ.) / 1.1×(vs C-BE) wall-clock speedups of D-BE, and the
  beyond-paper D-BE-vectorized device-resident variant.
* the engine's compile economy: evaluation rounds per strategy plus the
  engine's exact compile counters land in BENCH_mso.json so the perf
  trajectory accumulates across PRs.

Usage:
  python benchmarks/mso_walltime.py [--full] [--tiny] [--backend xla|
      pallas|pallas_interpret] [--out BENCH_mso.json]
"""
import argparse
import json
import platform
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp           # noqa: E402
import numpy as np                # noqa: E402

from repro.core.acquisition import logei_acq          # noqa: E402
from repro.core.mso import (MsoOptions, STRATEGIES,   # noqa: E402
                            maximize_acqf)
from repro.engine import EvalEngine, fused_logei_acq  # noqa: E402
from repro.gp.fit import fit_gp, standardize          # noqa: E402
from repro.gp.gpr import with_kinv                    # noqa: E402


def setup_gp(n: int, D: int, seed: int = 0, backend: str = "xla"):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, D))
    # high-frequency target -> short fitted lengthscales -> a wiggly,
    # multi-modal LogEI surface that makes the QN solvers actually work
    y = np.sin(8 * X).sum(1) + 0.3 * np.cos(13 * X[:, 0]) \
        + 0.05 * rng.standard_normal(n)
    y_std, _, _ = standardize(jnp.asarray(-y))
    gp = fit_gp(jnp.asarray(X), y_std, n_restarts=2, pad_bucket=32)
    if backend != "xla":
        gp = with_kinv(gp)
    return gp, float(jnp.max(y_std))


def bench(n: int, D: int, B: int = 10, reps: int = 5, seed: int = 0,
          backend: str = "xla"):
    gp, best = setup_gp(n, D, seed, backend)
    state = (gp, jnp.asarray(best))
    acq_fn = logei_acq if backend == "xla" else fused_logei_acq(backend)
    rng = np.random.default_rng(seed + 1)
    opts = MsoOptions(m=10, maxiter=200, pgtol=1e-5)
    rows = []
    for strategy in STRATEGIES:
        # fresh engine per strategy: compile counts are attributable
        engine = EvalEngine(acq_fn)
        walls, iters, rounds, evals = [], [], [], []
        for r in range(reps + 1):
            x0 = rng.uniform(0, 1, (B, D))
            res = maximize_acqf(acq_fn, x0, 0.0, 1.0, acq_state=state,
                                strategy=strategy, options=opts,
                                engine=engine)
            if r == 0:
                continue          # warm-up (jit compile)
            walls.append(res.wall_time)
            iters.append(float(np.median(res.n_iters)))
            rounds.append(res.n_rounds)
            evals.append(float(np.sum(res.n_evals)))
        es = engine.stats_snapshot()
        rows.append({
            "n": n, "D": D, "B": B, "strategy": strategy,
            "backend": backend,
            "wall_ms": 1e3 * float(np.median(walls)),
            "med_iters": float(np.median(iters)),
            "rounds": float(np.median(rounds)),
            # per-run solver totals (dbe_vec included: run_lockstep now
            # surfaces LbfgsbResult.rounds/n_evals into EngineStats)
            "evals_per_run": float(np.median(evals)),
            "eval_rounds_total": es["n_rounds"],
            "points_evaluated": es["n_points"],
            "points_padded": es["n_padded"],
            "engine_compiles": es["n_compiles"],
            "bucket_rounds": es["bucket_rounds"],
        })
    base = rows[0]["wall_ms"]
    cbe = rows[1]["wall_ms"]
    for r in rows:
        r["speedup_vs_seq"] = base / r["wall_ms"]
        r["speedup_vs_cbe"] = cbe / r["wall_ms"]
        print(f"mso,n={r['n']},D={r['D']},{r['strategy']},"
              f"wall={r['wall_ms']:.1f}ms,iters={r['med_iters']:.1f},"
              f"rounds={r['rounds']:.0f},"
              f"compiles={r['engine_compiles']},"
              f"vs_seq={r['speedup_vs_seq']:.2f}x", flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny B/D, 1 rep")
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "pallas_interpret"))
    ap.add_argument("--out", default="BENCH_mso.json")
    args = ap.parse_args(argv)

    if args.tiny:
        cases, B, reps = [(32, 3)], 4, 1
    elif args.full:
        cases, B, reps = [(64, 5), (128, 10), (192, 20), (288, 40)], 10, 5
    else:
        cases, B, reps = [(64, 5), (192, 5), (192, 20)], 10, 5

    out = []
    for n, D in cases:
        out.extend(bench(n, D, B=B, reps=reps, backend=args.backend))

    # headline scalars, one per (case, strategy) — dashboards and PR
    # diffs read these without walking the row arrays
    summary = {}
    for r in out:
        key = f"n{r['n']}_D{r['D']}_{r['strategy']}"
        summary[f"{key}_wall_ms"] = r["wall_ms"]
        summary[f"{key}_speedup_vs_seq"] = r["speedup_vs_seq"]

    record = {
        "bench": "mso_walltime",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "device": jax.devices()[0].device_kind,
        "jax_backend": jax.default_backend(),
        "python": platform.python_version(),
        "mode": ("tiny" if args.tiny else "full" if args.full
                 else "default"),
        "posterior_backend": args.backend,
        "summary": summary,
        "rows": out,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out} ({len(out)} rows)")
    return out


if __name__ == "__main__":
    main()
