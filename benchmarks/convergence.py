"""Paper Figures 2, 5 — C-BE convergence slowdown as B grows.

Rosenbrock (D=5, x ∈ [0,3]^D), L-BFGS-B m=10 (Fig 2) or BFGS (Fig 5).
For each B ∈ {1, 2, 5, 10}: run C-BE from random starts, record the mean
objective across the B points at every QN iteration, and report the median
iteration count to reach 1e-6 / 1e-12.  B=1 is SEQ. OPT. by definition;
the paper's observation is ~30 iters at B=1 vs >120 at B=10 for 1e-12.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                     # noqa: E402
from scipy.optimize import minimize    # noqa: E402

from benchmarks.offdiag import rosen_np, rosen_grad_np, _sum_obj, _sum_grad  # noqa: E402


def _traj_cbe(B, D, x0, method, maxiter=400):
    """Mean-objective trajectory of one C-BE run."""
    traj = []

    def cb(z):
        X = z.reshape(B, D) if not hasattr(z, "x") else z.x.reshape(B, D)
        traj.append(np.mean([rosen_np(X[b]) for b in range(B)]))

    opts = dict(maxiter=maxiter)
    kw = {}
    if method == "L-BFGS-B":
        opts.update(maxcor=10, gtol=1e-14, ftol=0.0)
        kw["bounds"] = [(0.0, 3.0)] * (B * D)
    else:
        opts.update(gtol=1e-14)
    minimize(lambda z: _sum_obj(z, B, D), x0.reshape(-1),
             jac=lambda z: _sum_grad(z, B, D), method=method,
             callback=cb, options=opts, **kw)
    return np.asarray(traj)


def iters_to(traj, tol):
    idx = np.nonzero(traj <= tol)[0]
    return int(idx[0]) + 1 if idx.size else len(traj) + 1


def run(method="L-BFGS-B", D=5, Bs=(1, 2, 5, 10), total_runs=64, seed=0,
        maxiter=400):
    rng = np.random.default_rng(seed)
    rows = []
    for B in Bs:
        reps = max(total_runs // B, 3)
        it6, it12 = [], []
        for _ in range(reps):
            x0 = rng.uniform(0.0, 3.0, (B, D))
            traj = _traj_cbe(B, D, x0, method, maxiter)
            it6.append(iters_to(traj, 1e-6))
            it12.append(iters_to(traj, 1e-12))
        rows.append({
            "method": method, "B": B, "reps": reps,
            "iters_to_1e-6": float(np.median(it6)),
            "iters_to_1e-12": float(np.median(it12)),
        })
    return rows


def main(full=False):
    total = 256 if full else 48
    out = []
    for method in ("L-BFGS-B", "BFGS"):
        for r in run(method=method, total_runs=total):
            out.append(r)
            print(f"convergence,{method},B={r['B']},"
                  f"iters@1e-6={r['iters_to_1e-6']:.1f},"
                  f"iters@1e-12={r['iters_to_1e-12']:.1f}")
    return out


if __name__ == "__main__":
    main()
