"""Batched serving with continuous batching on a reduced llama config.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("llama3.2-3b").reduced().replace(dtype="float32",
                                                      attn_chunk=16)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, slots=4, max_len=128)

    rng = np.random.default_rng(0)
    for uid in range(10):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                4 + uid % 5).astype(np.int32),
            max_new_tokens=12))
    done = eng.run_until_drained()
    print(f"served {len(done)} requests / {eng.stats['tokens']} tokens "
          f"in {eng.stats['steps']} steps "
          f"({eng.stats['wall']:.2f}s device time)")
    for r in done[:3]:
        print(f"  uid={r.uid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
