"""Compile-aware jit wrapper — the evaluation plane's cache primitive.

``CountingJit`` wraps a function in ``jax.jit`` with a side-effecting
trace counter: the increment executes at trace time only, so the counter
ticks exactly once per compiled executable and never on cache hits.  Both
the acquisition engine and the serving engine build their compiled planes
from this, which is what makes "compiles per run" a first-class, testable
metric (the ROADMAP's compilation-discipline requirement).

Beyond counting, the wrapper is a *retrace sanitizer*: every call builds
a cheap host-side signature of the jit cache key (static-arg values,
pytree structure, per-leaf shape/dtype/sharding) and, when a call traces,
diffs that signature against previously traced ones to classify **why**
— ``first-trace``, ``static-arg``, ``shape``, ``dtype``, ``sharding``,
``tree-structure``, or ``unknown``.  The classification is exposed via
:meth:`retrace_summary` and flows into engine ``stats_snapshot()``s and
the BENCH ``summary`` blocks, so a compile-count assertion failure in CI
names its cause instead of just its count.

Mesh-sharded callers (the fleet ask plane) pass ``in_shardings``: every
call then keys the jit cache on the (mesh, PartitionSpec) pair baked in
here — never on whichever device a host-built input happened to land on,
and never on which slots are live.  That is what keeps fleet compile
counts O(#buckets) and independent of the mesh's device count: a block's
programs are traced once per (bucket, slots) shape per mesh, no matter
how studies move across calls.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.obs.trace import instant as _obs_instant

# cap per-instance event history: retraces are supposed to be rare, and
# a misbehaving caller must not turn the sanitizer into a memory leak
_MAX_EVENTS = 256


def _leaf_sig(leaf: Any) -> Tuple:
    """(shape, dtype, sharding) for an array-ish leaf; scalars hash by
    type (a Python scalar is a weak-typed trace constant)."""
    shape = getattr(leaf, "shape", None)
    if shape is None:
        return ("py", type(leaf).__name__)
    dtype = str(getattr(leaf, "dtype", ""))
    sh = getattr(leaf, "sharding", None)
    return (tuple(shape), dtype, str(sh) if sh is not None else "")


class CountingJit:
    """``jax.jit`` with an exact retrace/compile counter and per-retrace
    cause classification."""

    def __init__(self, fn: Callable, *,
                 static_argnums: Sequence[int] = (),
                 donate_argnums: Sequence[int] = (),
                 in_shardings: Optional[Any] = None,
                 out_shardings: Optional[Any] = None,
                 name: Optional[str] = None):
        self.n_compiles = 0
        self.n_calls = 0
        self.name = name or getattr(fn, "__name__", "jit")
        self._static = tuple(static_argnums)
        #: signatures of calls that traced, in trace order
        self._seen: List[Tuple] = []
        #: why each retrace after the first happened (bounded)
        self.retrace_events: List[Dict[str, Any]] = []

        def counted(*args, **kwargs):
            self.n_compiles += 1          # trace-time side effect
            return fn(*args, **kwargs)

        counted.__name__ = getattr(fn, "__name__", "counted")
        # donation lets steady-state callers (the fused ask path) reuse
        # their O(n²) GP buffers in place; XLA ignores it on CPU, so gate
        # there to avoid per-call "donated buffer unused" warnings
        if jax.default_backend() == "cpu":
            donate_argnums = ()
        kw: dict = {}
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            kw["out_shardings"] = out_shardings
        self._jit = jax.jit(counted,
                            static_argnums=tuple(static_argnums) or None,
                            donate_argnums=tuple(donate_argnums) or None,
                            **kw)

    # ------------------------------------------------- cache-key signature
    def _signature(self, args: tuple, kwargs: dict) -> Tuple:
        """Host-side mirror of the jit cache key: static-arg reprs plus
        (treedef, leaf shapes/dtypes/shardings) for the dynamic args."""
        statics = []
        dynamic = []
        for i, a in enumerate(args):
            if i in self._static:
                try:
                    statics.append((i, repr(a)))
                except Exception:
                    statics.append((i, f"<unreprable {type(a).__name__}>"))
            else:
                leaves, treedef = jax.tree_util.tree_flatten(a)
                dynamic.append((i, str(treedef),
                                tuple(_leaf_sig(x) for x in leaves)))
        for k in sorted(kwargs):
            leaves, treedef = jax.tree_util.tree_flatten(kwargs[k])
            dynamic.append((k, str(treedef),
                            tuple(_leaf_sig(x) for x in leaves)))
        return (tuple(statics), tuple(dynamic))

    @staticmethod
    def _diff(sig: Tuple, prev: Tuple) -> List[str]:
        """Which cache-key components differ between two signatures."""
        kinds = set()
        statics, dynamic = sig
        pstatics, pdynamic = prev
        if statics != pstatics:
            kinds.add("static-arg")
        if len(dynamic) != len(pdynamic):
            kinds.add("tree-structure")
            return sorted(kinds)
        for (pos, tree, leaves), (ppos, ptree, pleaves) in zip(dynamic,
                                                               pdynamic):
            if pos != ppos or tree != ptree or len(leaves) != len(pleaves):
                kinds.add("tree-structure")
                continue
            for leaf, pleaf in zip(leaves, pleaves):
                if leaf == pleaf:
                    continue
                if leaf[0] == "py" or pleaf[0] == "py":
                    kinds.add("tree-structure")
                    continue
                if leaf[0] != pleaf[0]:
                    kinds.add("shape")
                if leaf[1] != pleaf[1]:
                    kinds.add("dtype")
                if leaf[2] != pleaf[2]:
                    kinds.add("sharding")
        return sorted(kinds)

    def _classify(self, sig: Tuple) -> Tuple[str, str]:
        """(cause, detail) for a call that traced: diff against the
        closest previously traced signature."""
        if not self._seen:
            return "first-trace", ""
        best: Optional[List[str]] = None
        for prev in self._seen:
            kinds = self._diff(sig, prev)
            if not kinds:
                # identical host signature yet it retraced: jit-internal
                # (e.g. weak-type promotion or a cleared cache)
                return "unknown", "signature matches an earlier trace"
            if best is None or len(kinds) < len(best):
                best = kinds
        assert best is not None
        return ("+".join(best) if len(best) > 1 else best[0],
                "differs from nearest earlier trace in: " + ", ".join(best))

    # ------------------------------------------------------------- call
    def __call__(self, *args: Any, **kwargs: Any):
        self.n_calls += 1
        sig = self._signature(args, kwargs)
        before = self.n_compiles
        out = self._jit(*args, **kwargs)
        if self.n_compiles > before:
            cause, detail = self._classify(sig)
            if len(self.retrace_events) < _MAX_EVENTS:
                self.retrace_events.append({
                    "program": self.name, "call": self.n_calls,
                    "compile": self.n_compiles, "cause": cause,
                    "detail": detail})
            # flight recorder: every classified (re)trace is an instant,
            # so a compile-count regression is visible on the timeline
            _obs_instant("retrace", program=self.name, cause=cause,
                         call=self.n_calls, compile=self.n_compiles)
            self._seen.append(sig)
        return out

    # ------------------------------------------------------------ stats
    def retrace_summary(self) -> Dict[str, Any]:
        """``{"causes": {cause: count}, "events": [...]}`` for snapshot
        blocks; causes cover every trace including the first."""
        causes: Dict[str, int] = {}
        for ev in self.retrace_events:
            causes[ev["cause"]] = causes.get(ev["cause"], 0) + 1
        return {"causes": causes, "events": list(self.retrace_events)}


def retrace_report(programs: Dict[str, "CountingJit"]) -> Dict[str, Any]:
    """Merge per-program retrace summaries for an engine snapshot:
    ``{"causes": {...aggregated...}, "by_program": {name: causes}}``."""
    agg: Dict[str, int] = {}
    by_prog: Dict[str, Dict[str, int]] = {}
    for label, cj in programs.items():
        summ = cj.retrace_summary()
        by_prog[label] = summ["causes"]
        for cause, n in summ["causes"].items():
            agg[cause] = agg.get(cause, 0) + n
    return {"causes": agg, "by_program": by_prog}


def merge_retrace_reports(*reports: Dict[str, Any]) -> Dict[str, Any]:
    """Combine :func:`retrace_report` outputs from several planes (e.g.
    the eval engine + the fleet engine) into one, summing cause counts.
    Program labels are assumed distinct across planes."""
    agg: Dict[str, int] = {}
    by_prog: Dict[str, Dict[str, int]] = {}
    for rep in reports:
        for cause, n in rep["causes"].items():
            agg[cause] = agg.get(cause, 0) + n
        by_prog.update(rep["by_program"])
    return {"causes": agg, "by_program": by_prog}
