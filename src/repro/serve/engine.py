"""Batched serving engine: prefill + greedy decode with continuous batching.

Slot-based continuous batching: a fixed batch of decode slots; when a
sequence finishes (EOS or max length) its slot is refilled from the pending
queue at the next step boundary.  Every step is ONE jitted program over the
full slot batch with *per-slot positions* — idle slots carry position −1 and
their cache writes land in a reserved trash slot (see layers.apply_attention),
so heterogeneous slot progress never corrupts live entries.  On the
production mesh the same decode fn lowers with the cache sharded per
DESIGN.md §6.

The compiled step goes through the evaluation plane's ``CountingJit``
(same primitive as ``repro.engine.EvalEngine``), so serving exposes the
same first-class compile accounting as the BO engine: ``stats["compiles"]``
must stay at 1 across a steady-state run — a second trace means a shape
leaked into the hot loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.cache import CountingJit
from repro.models import lm
from repro.models.config import ModelConfig

Array = jax.Array


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 32
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 max_len: int = 512, eos_id: int = -1,
                 prefill_chunk: Optional[int] = None):
        if cfg.family == "encdec":
            raise NotImplementedError(
                "engine serves decoder-only archs; whisper uses "
                "whisper.decode_step directly")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # cap prefill steps per step() call (None = drain): bounds how
        # long a newly admitted long prompt can stall decode; mid-prefill
        # slots resume from their per-slot offset at the next boundary
        self.prefill_chunk = prefill_chunk
        self.cache = lm.init_cache(cfg, slots, max_len)
        self.positions = np.zeros((slots,), np.int64)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self._step_fn = CountingJit(
            lambda p, t, c, i: lm.decode_step(p, cfg, t, c, i))
        self._prefilling: set = set()     # slots mid-prefill (per-slot pos)
        self.stats: Dict[str, Any] = {"steps": 0, "tokens": 0, "wall": 0.0,
                                      "compiles": 0}

    # ---------------------------------------------------------------- api
    def submit(self, req: Request):
        self.queue.append(req)

    def _batched_step(self, toks: np.ndarray, pos: np.ndarray):
        """One jitted step; pos < 0 marks idle rows (trash-slot writes)."""
        t0 = time.perf_counter()
        logits, self.cache = self._step_fn(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos, jnp.int32))
        self.stats["wall"] += time.perf_counter() - t0
        self.stats["steps"] += 1
        self.stats["compiles"] = self._step_fn.n_compiles
        return np.asarray(logits)

    def _fill_slots(self):
        """Admit queued requests, then advance prefill for every slot
        still prefilling — each from its own per-slot offset
        (``positions[s]``), so slots admitted at different step
        boundaries share prefill steps without anyone restarting at
        token 0 (idle/established slots ride along masked).  With
        ``prefill_chunk`` set, at most that many prefill steps run per
        call and unfinished slots stay in ``self._prefilling``, resuming
        from their offsets at the next boundary."""
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                self.positions[s] = 0
                self.cache = lm.reset_slot(self.cfg, self.cache, s)
                if len(req.prompt) > 1:
                    self._prefilling.add(s)
        budget = self.prefill_chunk
        while self._prefilling and (budget is None or budget > 0):
            toks = np.zeros((self.slots, 1), np.int32)
            pos = np.full((self.slots,), -1, np.int64)
            done = []
            for s in self._prefilling:
                prompt = self.active[s].prompt
                i = int(self.positions[s])          # per-slot offset
                toks[s, 0] = int(prompt[i])
                pos[s] = i
                self.positions[s] = i + 1
                if i + 1 >= len(prompt) - 1:        # last prompt token is
                    done.append(s)                  # fed by the decode step
            self._batched_step(toks, pos)
            for s in done:
                self._prefilling.discard(s)
            if budget is not None:
                budget -= 1

    def step(self) -> int:
        """One synchronized decode step over all ready slots (mid-prefill
        slots keep prefilling instead); returns #tokens."""
        self._fill_slots()
        act = [s for s in range(self.slots)
               if self.active[s] is not None and s not in self._prefilling]
        if not act:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        pos = np.full((self.slots,), -1, np.int64)
        for s in act:
            req = self.active[s]
            toks[s, 0] = req.out_tokens[-1] if req.out_tokens else \
                int(req.prompt[-1])
            pos[s] = self.positions[s]
        logits = self._batched_step(toks, pos)
        nxt = np.argmax(logits, -1)
        emitted = 0
        for s in act:
            req = self.active[s]
            req.out_tokens.append(int(nxt[s]))
            self.positions[s] += 1
            emitted += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or int(nxt[s]) == self.eos_id
                    or self.positions[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
        self.stats["tokens"] += emitted
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            before = list(self.active)
            self.step()
            for a in before:
                if a is not None and a.done:
                    finished.append(a)
        return finished
