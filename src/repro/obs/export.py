"""Exporters: Chrome-trace/Perfetto JSON from live traces or WAL journals.

Two sources feed the same format:

* **live** — :func:`chrome_trace` wraps a :class:`~repro.obs.trace.
  Tracer`'s event ring (already Chrome-shaped) with the container dict
  and process/thread metadata Perfetto uses for track names;
* **post-mortem** — :func:`timeline_from_journal` reconstructs a
  timeline from any PR-7/8 WAL journal, tracing *off*: the journal's
  monotone ``seq`` becomes the time axis (1 ms per record — the WAL
  orders events, it does not timestamp them), fleet scheduler ops land
  on per-study tracks, service ops on per-tenant tracks, and each
  request's ``svc_ask → svc_done/svc_shed`` lifecycle becomes a span.
  Crashed runs replay through the journal's own torn-record truncation,
  so the flight recorder works exactly where it matters most.

:func:`validate_chrome_trace` is the structural contract both paths are
tested against (and what ``python -m repro.obs validate`` runs in CI);
:func:`phase_breakdown` turns span events into the per-phase latency
blocks the BENCH writers embed in their ``summary``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Mapping, Optional, Sequence

# pid values for reconstructed timelines (Perfetto shows them as
# separate process tracks); live traces use the real os.getpid()
FLEET_PID = 1
SVC_PID = 2

_SVC_OPS_TENANT_TRACK = ("svc_ask", "svc_reject", "svc_dispatch",
                         "svc_done", "svc_retry", "svc_shed",
                         "svc_degrade", "svc_shed_tenant")


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[Dict[str, Any]]:
    evs: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "ts": 0, "args": {"name": name}}]
    if tid is not None:
        evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "ts": 0, "args": {"name": tname}})
    return evs


def chrome_trace(events: Sequence[Mapping[str, Any]],
                 process_name: str = "repro",
                 meta: Optional[Mapping[str, Any]] = None
                 ) -> Dict[str, Any]:
    """Wrap already Chrome-shaped events into a loadable trace object,
    adding process-name metadata for every pid seen."""
    evs: List[Dict[str, Any]] = []
    for pid in sorted({e.get("pid", 0) for e in events}):
        evs.extend(_meta(pid, process_name))
    evs.extend(dict(e) for e in events)
    out: Dict[str, Any] = {"traceEvents": evs, "displayTimeUnit": "ms"}
    if meta:
        out["otherData"] = dict(meta)
    return out


def write_chrome_trace(path: str, events: Sequence[Mapping[str, Any]],
                       process_name: str = "repro",
                       meta: Optional[Mapping[str, Any]] = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(events, process_name, meta), f, indent=1)


def validate_chrome_trace(obj: Any) -> List[str]:
    """Structural validation of a Chrome-trace JSON object (the subset
    Perfetto's importer requires).  Returns error strings; empty means
    the trace loads."""
    errors: List[str] = []
    if not isinstance(obj, Mapping):
        return [f"top level is {type(obj).__name__}, expected object"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, Mapping):
            errors.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing string 'name'")
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            errors.append(f"{where}: missing phase 'ph'")
            continue
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errors.append(f"{where}: missing integer {k!r}")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"{where}: missing numeric 'ts'")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: 'X' event needs dur >= 0, "
                              f"got {dur!r}")
        if "args" in ev and not isinstance(ev["args"], Mapping):
            errors.append(f"{where}: 'args' is not an object")
        if len(errors) >= 50:
            errors.append("... (truncated)")
            break
    return errors


def _quantile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


def phase_breakdown(events: Sequence[Mapping[str, Any]]
                    ) -> Dict[str, Dict[str, float]]:
    """Per-span-name latency stats over 'X' events, for BENCH summary
    blocks: ``{name: {count, total_ms, p50_ms, p95_ms, p99_ms}}``."""
    by_name: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            by_name.setdefault(ev["name"], []).append(
                float(ev.get("dur", 0.0)) / 1e3)
    out: Dict[str, Dict[str, float]] = {}
    for name, ms in sorted(by_name.items()):
        ms.sort()
        out[name] = {
            "count": len(ms),
            "total_ms": round(sum(ms), 3),
            "p50_ms": round(_quantile(ms, 0.50), 3),
            "p95_ms": round(_quantile(ms, 0.95), 3),
            "p99_ms": round(_quantile(ms, 0.99), 3),
        }
    return out


# --------------------------------------------------- WAL reconstruction

def _strip(rec: Mapping[str, Any], *drop: str) -> Dict[str, Any]:
    return {k: v for k, v in rec.items()
            if k not in drop and k != "seq" and k != "op"}


def timeline_from_journal(journal_dir: str) -> Dict[str, Any]:
    """Reconstruct a Chrome-trace timeline from a WAL journal directory.

    ``seq`` is the clock (1 ms per record).  Tracks: the fleet plane
    gets one thread per study plus a scheduler thread; the service
    plane one thread per tenant plus a controller thread.  Request
    lifecycles (``svc_ask`` .. ``svc_done``/``svc_shed``) render as
    complete spans on the owning tenant's track; everything else is an
    instant carrying the record's fields.
    """
    import os

    from repro.bo.journal import JOURNAL_NAME, StudyJournal

    # pure read: never truncate or open-for-append a journal we are only
    # inspecting — a post-mortem must not alter the evidence
    path = os.path.join(journal_dir, JOURNAL_NAME)
    records, truncated_bytes = StudyJournal._scan_and_truncate(
        path, truncate=False)

    def ts(rec: Mapping[str, Any]) -> float:
        return 1e3 * float(rec.get("seq", 0))

    events: List[Dict[str, Any]] = []
    tenant_tids: Dict[str, int] = {}
    open_reqs: Dict[Any, Dict[str, Any]] = {}
    studies: set = set()
    last_ts = 0.0

    def tenant_tid(name: str) -> int:
        if name not in tenant_tids:
            tenant_tids[name] = len(tenant_tids) + 1
        return tenant_tids[name]

    for rec in records:
        op = rec.get("op", "?")
        t = ts(rec)
        last_ts = max(last_ts, t)
        if op.startswith("svc_"):
            pid = SVC_PID
            tenant = rec.get("tenant")
            rid = rec.get("req")
            if tenant is None and rid is not None and rid in open_reqs:
                tenant = open_reqs[rid]["tenant"]
            on_tenant_track = (op in _SVC_OPS_TENANT_TRACK
                               and tenant is not None)
            tid = tenant_tid(tenant) if on_tenant_track else 0
            if op == "svc_ask":
                open_reqs[rid] = {"tenant": tenant, "ts": t,
                                  "deadline": rec.get("deadline")}
            elif op in ("svc_done", "svc_shed") and rid in open_reqs:
                o = open_reqs.pop(rid)
                name = "request" if op == "svc_done" else \
                    f"request({rec.get('kind', 'shed')})"
                events.append({
                    "name": name, "ph": "X", "ts": o["ts"],
                    "dur": max(t - o["ts"], 1.0), "pid": pid, "tid": tid,
                    "args": {"req": rid, "tenant": tenant,
                             "deadline": o["deadline"]}})
            events.append({"name": op, "ph": "i", "ts": t, "s": "t",
                           "pid": pid, "tid": tid,
                           "args": _strip(rec, "x")})
        else:
            pid = FLEET_PID
            sid = rec.get("study", rec.get("sid"))
            tid = int(sid) + 1 if isinstance(sid, int) else 0
            if isinstance(sid, int):
                studies.add(sid)
            events.append({"name": op, "ph": "i", "ts": t, "s": "t",
                           "pid": pid, "tid": tid,
                           "args": _strip(rec, "x")})

    # requests still in flight at the end of the journal (crash /
    # truncation): draw them to the last seq so they are visible
    for rid, o in open_reqs.items():
        events.append({
            "name": "request(inflight)", "ph": "X", "ts": o["ts"],
            "dur": max(last_ts - o["ts"], 1.0), "pid": SVC_PID,
            "tid": tenant_tid(o["tenant"]) if o["tenant"] else 0,
            "args": {"req": rid, "tenant": o["tenant"], "open": True}})

    meta_evs: List[Dict[str, Any]] = []
    meta_evs.extend(_meta(FLEET_PID, "fleet plane", 0, "scheduler"))
    for sid in sorted(studies):
        meta_evs.extend(_meta(FLEET_PID, "fleet plane",
                              sid + 1, f"study {sid}")[1:])
    if any(e["pid"] == SVC_PID for e in events):
        meta_evs.extend(_meta(SVC_PID, "service plane", 0, "controller"))
        for name, tid in sorted(tenant_tids.items()):
            meta_evs.extend(_meta(SVC_PID, "service plane",
                                  tid, f"tenant {name}")[1:])

    return {"traceEvents": meta_evs + events, "displayTimeUnit": "ms",
            "otherData": {"source": "wal-journal",
                          "journal_dir": journal_dir,
                          "n_records": len(records),
                          "truncated_bytes": truncated_bytes}}
