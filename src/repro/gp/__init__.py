from repro.gp.kernels import KernelParams, matern52, rbf, gram
from repro.gp.gpr import (GPState, fit_gram, predict,
                          log_marginal_likelihood,
                          log_marginal_likelihood_masked, pad_gp)
from repro.gp.fit import fit_gp, standardize
