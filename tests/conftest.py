import os
import subprocess
import sys
import textwrap

import jax
import pytest

# BO-side numerics (GP Cholesky, L-BFGS-B trajectories) need f64; model
# tests pass explicit dtypes throughout so this is safe globally.
# NOTE: the 512-device dry-run flag is deliberately NOT set here — tests
# that need a mesh spawn subprocesses via the ``run_sub`` fixture below.
jax.config.update("jax_enable_x64", True)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` virtual CPU
    devices.  Mesh-requiring tests use this so the host-device-count flag
    never leaks into the rest of the suite (the dry-run isolation
    requirement); asserts a clean exit and returns captured stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.fixture(name="run_sub")
def run_sub_fixture():
    """Fixture handle on :func:`run_sub` for mesh subprocess tests."""
    return run_sub
