"""Assigned input-shape cells + dry-run lowering targets.

Each cell pairs an architecture with one of the four assigned shapes and
produces (step_fn, arg ShapeDtypeStructs, in_shardings) for
``jax.jit(...).lower(...)`` — weak-type-correct, shardable, zero device
allocation.

Cell eligibility (DESIGN.md §5): ``long_500k`` needs sub-quadratic decode
(RG-LRU hybrid, xLSTM); pure full-attention archs skip it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (Boxed, is_boxed, param_pspecs,
                                        pspec, unbox)
from repro.models import lm
from repro.models import whisper as wh
from repro.models.config import ModelConfig
from repro.train.optim import OptimConfig, init_opt_state, zero1_pspec
from repro.train.step import make_train_step


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention is O(S²) — 500k decode infeasible"
    return True, ""


# ---------------------------------------------------------------------------
# shape-only param/state construction (jax.eval_shape: no allocation)
# ---------------------------------------------------------------------------

def init_fn_for(cfg: ModelConfig):
    return wh.init_params if cfg.family == "encdec" else lm.init_params


def params_shapes(cfg: ModelConfig):
    """Boxed tree of ShapeDtypeStructs + the matching PartitionSpec tree."""
    init = init_fn_for(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    boxed = jax.eval_shape(lambda k: init(k, cfg), key)
    return boxed


def _mesh_dict(mesh: Mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _sds_tree_shardings(tree, axes_fn, mesh: Mesh):
    """NamedShardings for a plain SDS tree via path-based logical axes."""
    md = _mesh_dict(mesh)

    def one(path, leaf):
        axes = axes_fn(path, leaf)
        return NamedSharding(mesh, pspec(leaf.shape, axes,
                                         mesh.axis_names, md))
    return jax.tree_util.tree_map_with_path(one, tree)


def _cache_axes(path, leaf):
    """Logical axes for decode-cache leaves, keyed by tree path."""
    keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
    name = keys[-1] if isinstance(keys[-1], str) else None
    nd = len(leaf.shape)
    in_mlstm = "mlstm" in keys

    if name in ("k", "v"):
        return (None, "batch_full", "kv_seq", "kv_heads", "head")[:nd] \
            if nd == 5 else (None,) * (nd - 4) + \
            ("batch_full", "kv_seq", "kv_heads", "head")
    if name == "pos":
        return (None,) * (nd - 2) + ("batch_full", "kv_seq")
    if name == "enc_pos":
        return ("batch_full", None)
    if name == "conv":
        return (None,) * (nd - 3) + ("batch_full", None, "lru")
    if name == "h":
        return (None,) * (nd - 2) + ("batch_full", "lru")
    if in_mlstm:
        # (G, n_m, B, H, dk, dv) / (G, n_m, B, H, dk) / (G, n_m, B, H)
        return (None, None, "batch_full") + (None,) * (nd - 4) + \
            (("lru",) if nd >= 5 else ())
    # slstm states (G, B, W) and anything else
    if nd >= 2:
        return (None,) * (nd - 2) + ("batch_full", "lru")
    return (None,) * nd


# ---------------------------------------------------------------------------
# lowering targets
# ---------------------------------------------------------------------------

def default_grad_accum(shape: ShapeCell, mesh: Mesh) -> int:
    """Baseline microbatching: one batch row per device per microbatch —
    the memory-safe default the §Perf hillclimb starts from."""
    md = _mesh_dict(mesh)
    dp = 1
    for ax in ("pod", "data"):
        if ax in md and shape.global_batch % (dp * md[ax]) == 0:
            dp *= md[ax]
    return max(shape.global_batch // dp, 1)


def build_cell(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh, *,
               opt_cfg: Optional[OptimConfig] = None,
               grad_accum: Optional[int] = None):
    """→ (step_fn, args (tuple of SDS pytrees), in_shardings,
    out_shardings, donate_argnums).

    out_shardings pins state outputs to their input layouts: donation then
    aliases params/opt/caches in place, and the optimizer's ZeRO-domain
    update all-gathers exactly once (bf16) at the jit boundary.
    """
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape.name}: {why}")
    if grad_accum is None:
        grad_accum = default_grad_accum(shape, mesh) \
            if shape.kind == "train" else 1

    md = _mesh_dict(mesh)
    dt = jnp.dtype(cfg.dtype)
    # params stay Boxed end-to-end (model code reads .value); sharding
    # trees mirror the Boxed structure so pytree flattening lines up.
    params_sds = params_shapes(cfg)

    def _spec_of(b: Boxed) -> P:
        base = pspec(b.value.shape, b.axes, mesh.axis_names, md)
        if cfg.fsdp:
            # ZeRO-3/FSDP: shard params over "data" as well; GSPMD
            # all-gathers per-layer at use and reduce-scatters grads.
            base = zero1_pspec(base, b.value.shape, mesh.axis_names, md)
        return base

    p_shard = jax.tree.map(
        lambda b: Boxed(NamedSharding(mesh, _spec_of(b)), b.axes),
        params_sds, is_leaf=is_boxed)

    B, S = shape.global_batch, shape.seq_len
    tok_shard = NamedSharding(
        mesh, pspec((B, S), ("batch", None), mesh.axis_names, md))

    if shape.kind == "train":
        opt_cfg = opt_cfg or OptimConfig()
        opt_sds = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), params_sds)

        def moment_shard(b: Boxed) -> Boxed:
            # ZeRO-1: extend the param spec with a "data" shard
            z = zero1_pspec(_spec_of(b), b.value.shape,
                            mesh.axis_names, md) if opt_cfg.zero1 \
                else _spec_of(b)
            return Boxed(NamedSharding(mesh, z), b.axes)

        mu_shard = jax.tree.map(moment_shard, params_sds, is_leaf=is_boxed)
        nu_shard = jax.tree.map(moment_shard, params_sds, is_leaf=is_boxed)
        ef_shard = jax.tree.map(moment_shard, params_sds,
                                is_leaf=is_boxed) \
            if opt_cfg.grad_compression == "int8_ef" else ()
        opt_shard = type(opt_sds)(
            step=NamedSharding(mesh, P()), mu=mu_shard, nu=nu_shard,
            ef=ef_shard)

        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        batch_shard = {"tokens": tok_shard, "targets": tok_shard}
        if cfg.family == "encdec":
            S_enc = max(int(S * cfg.enc_seq_fraction), 8)
            batch_sds["frames"] = jax.ShapeDtypeStruct(
                (B, S_enc, cfg.d_model), jnp.float32)
            batch_shard["frames"] = NamedSharding(
                mesh, pspec((B, S_enc, cfg.d_model),
                            ("batch", None, None), mesh.axis_names, md))

        step = make_train_step(cfg, opt_cfg, grad_accum=grad_accum)
        args = (params_sds, opt_sds, batch_sds)
        shards = (p_shard, opt_shard, batch_shard)
        out_shards = (p_shard, opt_shard, None)      # metrics: XLA's choice
        return step, args, shards, out_shards, (0, 1)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            S_enc = max(int(S * cfg.enc_seq_fraction), 8)
            S_dec = S - S_enc

            def step(params, frames, tokens):
                enc = wh.encode(params, cfg, frames)
                hid = wh.decode_train(params, cfg, enc, tokens)
                from repro.models.layers import lm_logits
                return lm_logits(params["embed"], cfg, hid[:, -1:, :])

            frames_sds = jax.ShapeDtypeStruct((B, S_enc, cfg.d_model),
                                              jnp.float32)
            tokens_sds = jax.ShapeDtypeStruct((B, S_dec), jnp.int32)
            f_shard = NamedSharding(mesh, pspec(
                (B, S_enc, cfg.d_model), ("batch", None, None),
                mesh.axis_names, md))
            t_shard = NamedSharding(mesh, pspec(
                (B, S_dec), ("batch", None), mesh.axis_names, md))
            return step, (params_sds, frames_sds, tokens_sds), \
                (p_shard, f_shard, t_shard), None, ()

        def step(params, tokens):
            hid, _ = lm.forward(params, cfg, tokens)
            from repro.models.layers import lm_logits
            return lm_logits(params["embed"], cfg, hid[:, -1:, :])

        tokens_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return step, (params_sds, tokens_sds), (p_shard, tok_shard), \
            None, ()

    # ---- decode ------------------------------------------------------------
    if cfg.family == "encdec":
        S_enc = 1500      # whisper-native encoder length for decode cells
        enc_sds = jax.ShapeDtypeStruct((B, S_enc, cfg.d_model), dt)
        cache_sds = jax.eval_shape(
            lambda p, e: wh.init_cache(p, cfg, e, B, S),
            params_sds, enc_sds)

        def step(params, tokens, cache, position):
            return wh.decode_step(params, cfg, tokens, cache, position)
    else:
        cache_sds = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))

        def step(params, tokens, cache, position):
            return lm.decode_step(params, cfg, tokens, cache, position)

    cache_shard = _sds_tree_shardings(cache_sds, _cache_axes, mesh)
    tokens_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok1_shard = NamedSharding(mesh, pspec((B, 1), ("batch", None),
                                           mesh.axis_names, md))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    args = (params_sds, tokens_sds, cache_sds, pos_sds)
    shards = (p_shard, tok1_shard, cache_shard, pos_shard)
    out_shards = (None, cache_shard)    # cache out == cache in → aliases
    return step, args, shards, out_shards, (2,)
