"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, fine-grained (d_ff=768).
48L d_model=2048 32H (GQA kv=4) vocab=151936.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    n_experts=128, experts_per_token=8,
    qk_norm=True, norm="rmsnorm", activation="swiglu",
    sub_quadratic=False,
)
