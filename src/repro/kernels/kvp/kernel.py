"""Fused cross-kernel × vector product (GP posterior mean) in Pallas.

Per batched acquisition evaluation D-BE issues ``mean = k(Xq, Xtr) @ α`` for
the whole restart batch.  Materializing the (q, n) cross gram in HBM costs
2·q·n·4 bytes of traffic it immediately re-reads; this kernel streams
training-point tiles through VMEM and accumulates the matvec in-register,
so HBM sees only the (q,) output — the memory-roofline-optimal form.

Grid: (q_tiles, n_tiles); the n axis is the reduction — the output block
index map ignores ``j``, so Pallas keeps the (TILE_Q, 1) accumulator in VMEM
across the whole reduction sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 2.2360679774997896

TILE_Q = 128
TILE_N = 128


def _kvp_kernel(q_ref, t_ref, qsq_ref, tsq_ref, alpha_ref, amp_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = q_ref[...]                       # (TILE_Q, D)
    b = t_ref[...]                       # (TILE_N, D)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = qsq_ref[...] + tsq_ref[...].T - 2.0 * ab
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-36)
    k = amp_ref[0, 0] * (1.0 + SQRT5 * r + (5.0 / 3.0) * d2) * \
        jnp.exp(-SQRT5 * r)              # (TILE_Q, TILE_N)
    out_ref[...] += k @ alpha_ref[...]   # (TILE_Q, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kvp(xq: jax.Array, xt: jax.Array, alpha: jax.Array,
        inv_lengthscale: jax.Array, amplitude: jax.Array,
        *, interpret: bool = False) -> jax.Array:
    """(q,) = matern52(xq, xt) @ alpha, cross gram never leaves VMEM."""
    nq, d = xq.shape
    nt = xt.shape[0]
    dtype = xq.dtype

    a = (xq * inv_lengthscale).astype(jnp.float32)
    b = (xt * inv_lengthscale).astype(jnp.float32)
    q_pad = (-nq) % TILE_Q
    n_pad = (-nt) % TILE_N
    a = jnp.pad(a, ((0, q_pad), (0, 0)))
    # pad alpha with zeros: padded training points contribute nothing
    b = jnp.pad(b, ((0, n_pad), (0, 0)))
    al = jnp.pad(alpha.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    asq = jnp.sum(a * a, -1, keepdims=True)
    bsq = jnp.sum(b * b, -1, keepdims=True)
    amp = jnp.asarray(amplitude, jnp.float32).reshape(1, 1)

    Q, N = a.shape[0], b.shape[0]
    grid = (Q // TILE_Q, N // TILE_N)

    out = pl.pallas_call(
        _kvp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_Q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, d), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_Q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_Q, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.float32),
        interpret=interpret,
    )(a, b, asq, bsq, al, amp)

    return out[:nq, 0].astype(dtype)
