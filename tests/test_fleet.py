"""Fleet ask plane tests: vmapped GP cores vs sequential calls, slot /
batch-composition independence (bitwise), compile economy independent of
fleet size, and the leading-batch lockstep solver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bo.sampler import FleetSampler, GPSampler
from repro.bo.space import BoxSpace
from repro.core.lbfgsb import LbfgsbOptions, lbfgsb_minimize
from repro.core.mso import MsoOptions
from repro.engine import EvalEngine, FleetConfig, FleetEngine
from repro.engine.ask import incr_core, refit_core
from repro.gp.fit import (FIT_OPTS, _FAR, pad_bucket_for, theta_bounds,
                          theta_init_grid)
from repro.launch.mesh import make_fleet_mesh

_MSO = MsoOptions(maxiter=40, pgtol=1e-2)


def _sphere(x):
    return float(np.sum((x - 0.4) ** 2))


def _fleet_kw(**over):
    kw = dict(n_startup_trials=4, n_restarts=4, pad_multiple=8,
              posterior_backend="xla", mso_options=MsoOptions(**vars(_MSO)))
    kw.update(over)
    return kw


def _padded_study(rng, n, b, D):
    """One padded study: n live points in a b-row _FAR-padded buffer."""
    x = np.full((b, D), _FAR) + np.arange(b)[:, None]
    x[:n] = rng.uniform(0, 1, (n, D))
    y = np.zeros((b,))
    y[:n] = np.sin(4 * x[:n]).sum(1)
    return jnp.asarray(x), jnp.asarray(y)


# ------------------------------------------------------- vmapped GP cores
def test_vmapped_refit_core_matches_sequential():
    """fit_padded_core under jax.vmap with heterogeneous per-study n
    masks == per-study sequential calls to <=1e-8 (both backends' output
    set: theta, chol, alpha, kinv)."""
    rng = np.random.default_rng(0)
    b, D, R = 16, 3, 2
    ns = [3, 7, 12, 16]                      # heterogeneous masks
    xs, ys = zip(*[_padded_study(rng, n, b, D) for n in ns])
    x, y = jnp.stack(xs), jnp.stack(ys)
    dt = x.dtype
    thetas = jnp.stack([theta_init_grid(D, dt, R, seed) for seed in ns])
    tlo, tup = theta_bounds(D, dt)
    tlo = jnp.broadcast_to(tlo, thetas.shape)
    tup = jnp.broadcast_to(tup, thetas.shape)
    nv = jnp.asarray(ns, jnp.int32)

    def core(x_s, y_s, n_s, th, lo, up):
        return refit_core(x_s, y_s, n_s, th, lo, up, dim=D,
                          kernel="matern52", backend="pallas_interpret",
                          fit_opts=FIT_OPTS)

    out_v = jax.vmap(core)(x, y, nv, thetas, tlo, tup)
    for i in range(len(ns)):
        out_s = core(x[i], y[i], nv[i], thetas[i], tlo[i], tup[i])
        for leaf_v, leaf_s in zip(out_v, out_s):
            np.testing.assert_allclose(np.asarray(leaf_v[i]),
                                       np.asarray(leaf_s), atol=1e-8)


def test_vmapped_incr_core_matches_sequential_across_migration():
    """incremental_update (via incr_core) under jax.vmap with
    heterogeneous n: growing each study one observation at a time stays
    <=1e-8 vs per-study sequential calls, including after a bucket
    migration (host-compacted re-entry into a larger padded buffer)."""
    rng = np.random.default_rng(1)
    D, R = 2, 2
    S = 3
    live = [rng.uniform(0, 1, (20, D)) for _ in range(S)]
    yall = [np.sin(3 * X).sum(1) for X in live]

    def seeded(b, ns):
        """Stacked padded buffers + per-study full fits at count ns."""
        xs, ys, fits = [], [], []
        for s in range(S):
            x = np.full((b, D), _FAR) + np.arange(b)[:, None]
            x[:ns[s]] = live[s][:ns[s]]
            y = np.zeros((b,))
            y[:ns[s]] = yall[s][:ns[s]]
            x, y = jnp.asarray(x), jnp.asarray(y)
            th = theta_init_grid(D, x.dtype, R, s)
            lo, up = theta_bounds(D, x.dtype)
            fits.append(refit_core(
                x, y, jnp.asarray(ns[s]), th,
                jnp.broadcast_to(lo, th.shape), jnp.broadcast_to(up, th.shape),
                dim=D, kernel="matern52", backend="pallas_interpret",
                fit_opts=FIT_OPTS))
            xs.append(x)
            ys.append(y)
        return list(xs), list(ys), fits

    def check_growth(b, n0, steps):
        xs, ys, fits = seeded(b, [n0, n0 + 1, n0 + 2])
        theta = jnp.stack([f[2] for f in fits])
        chol = jnp.stack([f[3] for f in fits])
        kinv = jnp.stack([f[5] for f in fits])
        ns = [n0, n0 + 1, n0 + 2]
        for step in range(steps):
            for s in range(S):                  # append one obs per study
                i = ns[s]
                xs[s] = xs[s].at[i].set(jnp.asarray(live[s][i]))
                ys[s] = ys[s].at[i].set(float(yall[s][i]))
                ns[s] = i + 1
            x, y = jnp.stack(xs), jnp.stack(ys)
            nv = jnp.asarray(ns, jnp.int32)

            def core(x_s, y_s, n_s, th, ch, ki):
                out = incr_core(x_s, y_s, n_s, th, ch, ki, dim=D,
                                kernel="matern52")
                return out[3], out[4], out[5], out[6]

            ch_v, al_v, ki_v, ok_v = jax.vmap(core)(x, y, nv, theta,
                                                    chol, kinv)
            assert bool(jnp.all(ok_v))
            for s in range(S):
                ch_s, al_s, ki_s, ok_s = core(x[s], y[s], nv[s], theta[s],
                                              chol[s], kinv[s])
                assert bool(ok_s)
                np.testing.assert_allclose(np.asarray(ch_v[s]),
                                           np.asarray(ch_s), atol=1e-8)
                np.testing.assert_allclose(np.asarray(al_v[s]),
                                           np.asarray(al_s), atol=1e-8)
                np.testing.assert_allclose(np.asarray(ki_v[s]),
                                           np.asarray(ki_s), atol=1e-8)
            chol, kinv = ch_v, ki_v
        return ns

    ns = check_growth(b=8, n0=3, steps=3)       # fill the 8-bucket
    assert ns == [6, 7, 8]
    # bucket migration: re-enter a 16-row buffer (fresh factor, as the
    # fleet scheduler does) and keep growing incrementally there
    check_growth(b=16, n0=9, steps=4)


# --------------------------------------- slot / batch-composition freedom
def _drive(sampler_or_fleet, rounds, record_study=0):
    xs = []
    if isinstance(sampler_or_fleet, FleetSampler):
        for _ in range(rounds):
            trials = sampler_or_fleet.ask_all()
            xs.append(trials[record_study].x.copy())
            for s, t in enumerate(trials):
                sampler_or_fleet.tell(s, t.trial_id, _sphere(t.x))
    else:
        for _ in range(rounds):
            t = sampler_or_fleet.ask()
            xs.append(t.x.copy())
            sampler_or_fleet.tell(t.trial_id, _sphere(t.x))
    return np.array(xs)


def test_fleet_solo_equals_company_bitwise():
    """A study's trajectory is bit-for-bit independent of which other
    studies share the fleet batch (refit_interval=1, warm_start=False:
    the deterministic full-refit regime, crossing a bucket boundary)."""
    kw = _fleet_kw(refit_interval=1, warm_start=False)
    space = BoxSpace.cube(2, -1.0, 1.0)
    solo = FleetSampler(space, n_studies=1, seed=5, slots=4, **kw)
    company = FleetSampler(space, n_studies=4, seed=5, slots=4, **kw)
    xs_solo = _drive(solo, 12)
    xs_company = _drive(company, 12)
    np.testing.assert_array_equal(xs_solo, xs_company)
    assert company.fleet.n_migrations >= 4     # crossed the 8-bucket


def test_fleet_slot_permutation_bitwise():
    """Admission order permutes slot assignment; per-study results must
    not move by a single bit."""
    cfg = FleetConfig(dim=2, n_restarts=4, slots=4, pad_bucket=8,
                      refit_interval=2, warm_start=True,
                      gp_fit_restarts=2,
                      mso=LbfgsbOptions(m=10, maxiter=40, pgtol=1e-2,
                                        ftol=0.0, maxls=25))
    rng = np.random.default_rng(7)
    obs = {s: rng.uniform(0, 1, (4, 2)) for s in range(3)}

    def run(order):
        from repro.core.acquisition import logei_acq
        fleet = FleetEngine(EvalEngine(logei_acq), cfg)
        for sid in order:
            fleet.add_study(sid)
            for x in obs[sid]:
                fleet.observe(sid, x, _sphere(x))
        out = {}
        for trial in range(3):                  # full + incremental steps
            for sid in order:
                fleet.request_suggest(sid, jax.random.fold_in(
                    jax.random.PRNGKey(100 + sid), trial), fit_seed=sid)
            fleet.step()
            for sid in order:
                x, info = fleet.pop_result(sid)
                out.setdefault(sid, []).append((x, info.kind))
                fleet.observe(sid, np.clip(x, 0, 1),
                              _sphere(np.clip(x, 0, 1)))
        return out

    a = run([0, 1, 2])
    b = run([2, 0, 1])
    for sid in range(3):
        for (xa, ka), (xb, kb) in zip(a[sid], b[sid]):
            assert ka == kb
            np.testing.assert_array_equal(xa, xb)


def test_fleet_matches_askengine():
    """Fleet-served suggestions track the solo fused AskEngine pipeline
    (vmap lowering may shift last-ulp rounding; trajectories must agree
    to 1e-10 over a full run crossing a bucket boundary)."""
    kw = _fleet_kw(refit_interval=1, warm_start=False)
    space = BoxSpace.cube(2, -1.0, 1.0)
    ref = GPSampler(space, strategy="dbe_vec", fused=True, seed=5, **kw)
    fleet = FleetSampler(space, n_studies=1, seed=5, slots=2, **kw)
    xs_ref = _drive(ref, 12)
    xs_fleet = _drive(fleet, 12)
    np.testing.assert_allclose(xs_fleet, xs_ref, atol=1e-10)


# ----------------------------------------------------- scheduler economy
def test_fleet_compile_counts_independent_of_fleet_size():
    """3 programs per (bucket, slots) shape; serving more studies (same
    slot width) reuses the same executables — compile counts depend on
    the bucket ladder only, never on S."""
    space = BoxSpace.cube(2, -1.0, 1.0)
    counts = {}
    for S in (2, 4):
        fs = FleetSampler(space, n_studies=S, seed=0, slots=2,
                          **_fleet_kw(refit_interval=4))
        fs.optimize(_sphere, 10)                # startup 4 + 6 suggests
        snap = fs.stats_snapshot()
        n_buckets = len({blk.bucket for blk in fs.fleet._blocks})
        assert snap["n_fleet_compiles"] <= 3 * n_buckets
        counts[S] = (snap["n_fleet_compiles"], n_buckets)
    assert counts[2] == counts[4], counts


def test_fleet_incremental_steady_state_and_quality():
    """Defaults (incremental on, warm starts): rank-one steps dominate,
    no fallbacks, and the fleet still optimizes every study."""
    fs = FleetSampler(BoxSpace.cube(2, -1.0, 1.0), n_studies=3, seed=0,
                      slots=4, **_fleet_kw(refit_interval=6))
    best = fs.optimize(_sphere, 16)
    assert all(b.y < 0.25 for b in best), [b.y for b in best]
    snap = fs.stats_snapshot()
    assert snap["n_incremental"] > snap["n_full_refits"]
    assert snap["n_fallbacks"] == 0
    assert snap["n_migrations"] == 3            # every study crossed b=8
    # placement observability: every migration is classified, and on one
    # device every migration is trivially intra-device
    assert snap["n_migrations_intra"] + snap["n_migrations_cross"] \
        == snap["n_migrations"]
    assert snap["n_migrations_cross"] == 0
    assert snap["n_devices"] == 1
    assert snap["slots_per_device"] == [3]
    assert snap["queue_depth"] == 0


def test_fleet_stats_placement_keys():
    """stats_snapshot() placement observability: queue depth tracks the
    registered-but-unadmitted set; per-device occupancy tracks installs."""
    from repro.core.acquisition import logei_acq
    cfg = FleetConfig(dim=2, n_restarts=4, slots=2, pad_bucket=8,
                      mso=LbfgsbOptions(m=10, maxiter=20, pgtol=1e-2,
                                        ftol=0.0, maxls=25))
    fleet = FleetEngine(EvalEngine(logei_acq), cfg)
    fleet.add_study("a")
    fleet.add_study("b")
    snap = fleet.stats_snapshot()
    assert snap["n_devices"] == 1
    assert snap["slots_per_device"] == [0]
    assert snap["queue_depth"] == 2          # registered, not yet admitted
    rng = np.random.default_rng(0)
    for x in rng.uniform(0, 1, (2, 2)):
        fleet.observe("a", x, _sphere(x))
        fleet.observe("b", x, _sphere(x))
    fleet.request_suggest("a", jax.random.PRNGKey(0), fit_seed=0)
    fleet.step()
    snap = fleet.stats_snapshot()
    assert snap["queue_depth"] == 0
    assert snap["slots_per_device"] == [2]
    assert snap["n_migrations_intra"] == snap["n_migrations_cross"] == 0


def test_fleet_mesh1_matches_unsharded_bitwise():
    """A 1-device fleet mesh is pure plumbing: trajectories and compile
    counts match the unsharded fleet bit for bit (the in-process half of
    the placement-independence invariant; the multi-device half runs in
    tests/test_fleet_mesh.py subprocesses)."""
    kw = _fleet_kw(refit_interval=4)
    space = BoxSpace.cube(2, -1.0, 1.0)
    plain = FleetSampler(space, n_studies=3, seed=5, slots=3, **kw)
    meshed = FleetSampler(space, n_studies=3, seed=5, slots=3,
                          mesh=make_fleet_mesh(1), **kw)
    xs_plain = _drive(plain, 10)
    xs_mesh = _drive(meshed, 10)
    np.testing.assert_array_equal(xs_plain, xs_mesh)
    sp, sm = plain.stats_snapshot(), meshed.stats_snapshot()
    assert sp["n_fleet_compiles"] == sm["n_fleet_compiles"]
    assert sm["n_devices"] == 1


def test_fleet_admission_and_errors():
    from repro.core.acquisition import logei_acq
    cfg = FleetConfig(dim=2, n_restarts=4, slots=2, pad_bucket=8)
    fleet = FleetEngine(EvalEngine(logei_acq), cfg)
    fleet.add_study("a")
    with pytest.raises(ValueError, match="already registered"):
        fleet.add_study("a")
    fleet.observe("a", np.array([0.5, 0.5]), 1.0)
    fleet.request_suggest("a")
    with pytest.raises(ValueError, match=">= 2"):
        fleet.step()
    # a sampler attached mid-run must be rejected
    s = GPSampler(BoxSpace.cube(2, -1.0, 1.0), strategy="dbe_vec",
                  fused=True, n_startup_trials=1, n_restarts=4,
                  pad_multiple=8)
    t = s.ask()
    s.tell(t.trial_id, 1.0)
    with pytest.raises(ValueError, match="before the first trial"):
        s.attach_fleet(fleet)


# ------------------------------------------------- leading-batch solver
def test_lbfgsb_leading_batch_matches_2d():
    """(S, B, D) solves == the S independent (B, D) solves, bitwise: the
    flattened fleet shares rounds but frozen rows never move."""
    rng = np.random.default_rng(3)
    S, B, D = 3, 4, 2
    centers = jnp.asarray(rng.uniform(-1, 1, (S, 1, D)))

    def make_fun(c):
        def fun(xb):
            d = xb - c
            return jnp.sum(d * d, -1), 2.0 * d
        return fun

    def fleet_fun(x):                            # (S, B, D)
        d = x - centers
        return jnp.sum(d * d, -1), 2.0 * d

    x0 = jnp.asarray(rng.uniform(-2, 2, (S, B, D)))
    lo, up = -jnp.ones((D,)), jnp.ones((D,))
    opts = LbfgsbOptions(maxiter=50)
    res = lbfgsb_minimize(fleet_fun, x0, lo, up, opts)
    assert res.x.shape == (S, B, D)
    assert res.rounds.ndim == 0
    for s in range(S):
        ref = lbfgsb_minimize(make_fun(centers[s]), x0[s], lo, up, opts)
        np.testing.assert_array_equal(np.asarray(res.x[s]),
                                      np.asarray(ref.x))
        np.testing.assert_array_equal(np.asarray(res.f[s]),
                                      np.asarray(ref.f))
        np.testing.assert_array_equal(np.asarray(res.status[s]),
                                      np.asarray(ref.status))
