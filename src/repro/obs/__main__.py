"""CLI: ``python -m repro.obs <subcommand>`` — the flight-recorder tools.

Subcommands:

* ``timeline <journal_dir> [-o trace.json]`` — reconstruct a
  Chrome-trace/Perfetto timeline from a WAL journal (works on crashed
  runs with tracing off; the read never mutates the journal);
* ``validate <trace.json>`` — structural check that a trace file loads
  in Perfetto (the CI gate for exported artifacts);
* ``overhead [--n N] [--budget-ns NS]`` — microbenchmark the
  *disabled* tracer fast path (span + instant per iteration) and fail
  if it exceeds the per-call budget.  This is the enforceable proxy for
  the ≤1%-disabled-overhead acceptance bar: the default 5 µs budget is
  <1% of even a 0.5 ms steady-state ask, and the measured cost is
  typically well under 1 µs.

Exit status: 0 on success / valid / within budget, 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _cmd_timeline(args) -> int:
    from repro.obs import export

    trace = export.timeline_from_journal(args.journal_dir)
    errors = export.validate_chrome_trace(trace)
    if errors:
        for e in errors:
            print(f"error: {e}", file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(trace, f, indent=1)
    n = len(trace["traceEvents"])
    print(f"wrote {args.out} ({n} events from "
          f"{trace['otherData']['n_records']} journal records, "
          f"{trace['otherData']['truncated_bytes']} torn bytes)")
    return 0


def _cmd_validate(args) -> int:
    from repro.obs import export

    with open(args.trace) as f:
        obj = json.load(f)
    errors = export.validate_chrome_trace(obj)
    if errors:
        for e in errors:
            print(f"{args.trace}: {e}", file=sys.stderr)
        return 1
    print(f"{args.trace}: OK ({len(obj['traceEvents'])} events)")
    return 0


def _cmd_overhead(args) -> int:
    from repro.obs import trace

    trace.disable()                      # measure the off-by-default path
    n = args.n
    t0 = time.perf_counter()
    for _ in range(n):
        with trace.span("bench"):
            pass
        trace.instant("bench")
    per_call_ns = 1e9 * (time.perf_counter() - t0) / (2 * n)
    ok = per_call_ns <= args.budget_ns
    print(f"disabled tracer: {per_call_ns:.0f} ns per span/instant call "
          f"(budget {args.budget_ns} ns) — {'OK' if ok else 'OVER BUDGET'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="flight-recorder tools: WAL timeline reconstruction, "
                    "Chrome-trace validation, overhead budget check")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("timeline",
                       help="reconstruct a Perfetto timeline from a WAL "
                            "journal directory")
    p.add_argument("journal_dir")
    p.add_argument("-o", "--out", default="timeline.json")
    p.set_defaults(fn=_cmd_timeline)

    p = sub.add_parser("validate",
                       help="structurally validate a Chrome-trace JSON "
                            "file")
    p.add_argument("trace")
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("overhead",
                       help="microbench the disabled tracer fast path "
                            "against a per-call budget")
    p.add_argument("--n", type=int, default=200_000)
    p.add_argument("--budget-ns", type=float, default=5000.0)
    p.set_defaults(fn=_cmd_overhead)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
