"""Fixture: compile-economy-disciplined twin of ``recompile_hazard_bad``
— programs built once, keyed only on padded shape buckets.  Zero
``recompile-hazard`` findings."""
from repro.engine.cache import CountingJit


def _step(state, X):
    return X * 2.0


class Scheduler:
    def __init__(self, slots):
        self.slots = slots
        self._ask_jit = CountingJit(_step, static_argnums=())

    def ask(self, state, X_padded):
        # cache key is the padded bucket shape, never occupancy
        return self._ask_jit(state, X_padded)
