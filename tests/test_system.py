"""End-to-end system tests: the paper's pipeline (BO with D-BE inside)
driving real work, checkpoint/restart mid-run, and the HPO-over-trainer
integration."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.bo.objectives import make_objective
from repro.bo.sampler import GPSampler
from repro.bo.space import BoxSpace
from repro.core.mso import MsoOptions


def test_bo_end_to_end_strategies_agree():
    """All four MSO strategies drive BO to comparable optima on Sphere —
    the paper's 'comparable final objective values' claim (Table 1)."""
    D = 4
    obj = make_objective("sphere", D, seed=1)
    space = BoxSpace.cube(D, *obj.bounds)
    bests = {}
    for strategy in ("seq", "cbe", "dbe", "dbe_vec"):
        s = GPSampler(space, strategy=strategy, seed=0, n_startup_trials=6,
                      n_restarts=5,
                      mso_options=MsoOptions(maxiter=100, pgtol=1e-2))
        bests[strategy] = s.optimize(obj, 25).y
    v = np.array(list(bests.values()))
    assert np.all(v < 25.0), bests            # all clearly below random
    # D-BE must not degrade solution quality vs SEQ (within noise)
    assert bests["dbe"] < bests["seq"] * 5 + 1.0, bests


def test_bo_restart_from_journal_continues_improving():
    D = 3
    obj = make_objective("sphere", D, seed=2)
    space = BoxSpace.cube(D, *obj.bounds)
    s = GPSampler(space, strategy="dbe_vec", seed=1, n_startup_trials=5,
                  mso_options=MsoOptions(maxiter=60, pgtol=1e-2))
    s.optimize(obj, 12)
    best_before = s.best().y
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.json")
        s.save(path)
        s2 = GPSampler.load(path, n_startup_trials=5,
                            mso_options=MsoOptions(maxiter=60, pgtol=1e-2))
        s2.optimize(obj, 8)
        assert s2.best().y <= best_before + 1e-12


def test_hpo_over_tiny_trainer():
    """The control-plane/data-plane integration: BO tunes the learning
    rate of a real (reduced) LM training run and finds a better lr than
    the worst candidate."""
    from repro.configs import get_config
    from repro.data.synth import DataConfig, synth_batch
    from repro.models import lm
    from repro.train.optim import OptimConfig, init_opt_state
    from repro.train.step import make_train_step

    cfg = get_config("llama3.2-3b").reduced().replace(
        dtype="float32", attn_chunk=16, n_layers=2, d_model=64,
        d_ff=128, vocab_size=256)
    dcfg = DataConfig(global_batch=4, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v)
             for k, v in synth_batch(cfg, dcfg, 0).items()}

    def train_loss(log_lr: float) -> float:
        opt_cfg = OptimConfig(lr=float(10.0 ** log_lr), warmup_steps=1,
                              total_steps=12)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg))
        loss = None
        for _ in range(12):
            params, opt_state, m = step(params, opt_state, batch)
            loss = float(m["loss"])
        return loss if np.isfinite(loss) else 20.0

    space = BoxSpace(np.array([-5.0]), np.array([-0.5]))
    s = GPSampler(space, strategy="dbe", seed=0, n_startup_trials=4,
                  n_restarts=4,
                  mso_options=MsoOptions(maxiter=50, pgtol=1e-2))
    best = s.optimize(lambda x: train_loss(x[0]), 10)
    losses = [t.y for t in s.trials if t.state == "complete"]
    assert best.y <= np.median(losses), (best.y, losses)
