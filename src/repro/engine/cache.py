"""Compile-aware jit wrapper — the evaluation plane's cache primitive.

``CountingJit`` wraps a function in ``jax.jit`` with a side-effecting
trace counter: the increment executes at trace time only, so the counter
ticks exactly once per compiled executable and never on cache hits.  Both
the acquisition engine and the serving engine build their compiled planes
from this, which is what makes "compiles per run" a first-class, testable
metric (the ROADMAP's compilation-discipline requirement).
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax


class CountingJit:
    """``jax.jit`` with an exact retrace/compile counter."""

    def __init__(self, fn: Callable, *,
                 static_argnums: Sequence[int] = (),
                 donate_argnums: Sequence[int] = ()):
        self.n_compiles = 0

        def counted(*args, **kwargs):
            self.n_compiles += 1          # trace-time side effect
            return fn(*args, **kwargs)

        counted.__name__ = getattr(fn, "__name__", "counted")
        # donation lets steady-state callers (the fused ask path) reuse
        # their O(n²) GP buffers in place; XLA ignores it on CPU, so gate
        # there to avoid per-call "donated buffer unused" warnings
        if jax.default_backend() == "cpu":
            donate_argnums = ()
        self._jit = jax.jit(counted,
                            static_argnums=tuple(static_argnums) or None,
                            donate_argnums=tuple(donate_argnums) or None)

    def __call__(self, *args: Any, **kwargs: Any):
        return self._jit(*args, **kwargs)
