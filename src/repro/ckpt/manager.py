"""Checkpointing: atomic, async, mesh-independent (elastic) restore.

* **Atomic** — write to ``<dir>/tmp.<step>`` then ``os.replace`` into place;
  a crash mid-save never corrupts the latest checkpoint.
* **Async**  — the device→host gather happens synchronously (cheap), the
  file write runs on a daemon thread so the train loop keeps stepping.
* **Elastic** — arrays are saved *unsharded* (global view) with their tree
  paths as keys; restore `device_put`s onto whatever mesh/sharding the new
  job uses — 512→256 chips or a different mesh shape is a non-event.
* **Preemption** — `install_sigterm_handler` flips a flag the train loop
  polls; the loop checkpoints and exits cleanly (see launch/train.py).
"""
from __future__ import annotations

import os
import re
import signal
import threading
import time
import warnings
import zipfile
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        val = flat[key]
        if tuple(val.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {val.shape} vs "
                f"template {leaf.shape}")
        if val.dtype != np.asarray(leaf).dtype:
            # a silent downcast (f64 ckpt into an f32 template or vice
            # versa) corrupts bit-exactness guarantees downstream —
            # refuse, like a shape mismatch
            raise ValueError(
                f"dtype mismatch at {key}: ckpt {val.dtype} vs "
                f"template {np.asarray(leaf).dtype}")
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        # a crash mid-save leaves a .tmp_* behind (the os.replace never
        # ran); it is garbage by construction — sweep it on init
        for f in os.listdir(directory):
            if f.startswith(".tmp_"):
                try:
                    os.remove(os.path.join(directory, f))
                except OSError:
                    pass

    # ------------------------------------------------------------- paths
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.npz")

    def all_steps(self) -> List[int]:
        out = []
        for f in os.listdir(self.dir):
            m = re.match(r"ckpt_(\d+)\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _is_valid(self, step: int) -> bool:
        """A checkpoint counts only if its zip container is intact (a
        torn write that somehow survived, a truncated copy, bit rot)."""
        try:
            with np.load(self._path(step)) as z:
                z.files
            return True
        except (OSError, ValueError, zipfile.BadZipFile, EOFError):
            return False

    def latest_step(self) -> Optional[int]:
        """Newest *restorable* step: corrupt/partial checkpoints are
        skipped with a warning instead of poisoning recovery."""
        for step in reversed(self.all_steps()):
            if self._is_valid(step):
                return step
            warnings.warn(f"skipping corrupt checkpoint "
                          f"{self._path(step)}")
        return None

    # -------------------------------------------------------------- save
    def save(self, step: int, tree, *, block: bool = False):
        flat = _flatten(tree)            # sync device→host gather
        self.wait()                      # one in-flight save at a time

        def write():
            tmp = os.path.join(self.dir, f".tmp_{step}_{os.getpid()}")
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.replace(tmp, self._path(step))
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    # ------------------------------------------------------- flat dicts
    def save_flat(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        """Synchronously save a flat ``{name: array}`` dict (no pytree
        template needed to load it back — the study-journal snapshot
        path, where recovery has no template until the state is read)."""
        self.wait()
        tmp = os.path.join(self.dir, f".tmp_{step}_{os.getpid()}")
        with open(tmp, "wb") as f:
            np.savez(f, **{k: np.asarray(v) for k, v in flat.items()})
        os.replace(tmp, self._path(step))
        self._gc()

    def load_flat(self, step: int) -> Dict[str, np.ndarray]:
        with np.load(self._path(step)) as z:
            return {k: z[k] for k in z.files}

    # ----------------------------------------------------------- restore
    def restore(self, step: int, template, *, shardings=None):
        """Restore into ``template``'s structure; ``shardings`` (same
        structure, optional) places leaves onto the *current* mesh —
        this is the elastic-rescale path."""
        with np.load(self._path(step)) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree


# ---------------------------------------------------------------------------
# preemption handling
# ---------------------------------------------------------------------------

class PreemptionFlag:
    def __init__(self):
        self._evt = threading.Event()

    def set(self, *_):
        self._evt.set()

    @property
    def triggered(self) -> bool:
        return self._evt.is_set()


def install_sigterm_handler() -> PreemptionFlag:
    flag = PreemptionFlag()
    signal.signal(signal.SIGTERM, flag.set)
    signal.signal(signal.SIGUSR1, flag.set)
    return flag
