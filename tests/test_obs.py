"""Unified telemetry plane tests: tracer semantics (off-by-default,
ring bounds, thread safety), ProgramTimer passthrough, the metrics
registry + Prometheus exposition, the unified ``stats_snapshot()``
schema contract across all five engine layers, retrace-report merging
and the retrace-history cap, the AskEngine NaN guard, and Chrome-trace
export from both live tracers and WAL journals."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from faults import VirtualClock
from repro.analysis.runtime import (FiniteGuard, NonFiniteError,
                                    install_nan_guard, nan_guard_stats)
from repro.bo.sampler import FleetSampler, GPSampler
from repro.bo.space import BoxSpace
from repro.core.acquisition import logei_acq
from repro.core.mso import MsoOptions
from repro.engine import (AskConfig, AskEngine, EvalEngine, FleetConfig,
                          FleetEngine)
from repro.engine.cache import (CountingJit, merge_retrace_reports,
                                retrace_report)
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.bo_service import BOService, TenantConfig

_MSO = MsoOptions(maxiter=40, pgtol=1e-2)


def _sphere(x):
    return float(np.sum((x - 0.4) ** 2))


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test starts and ends with the off-by-default contract."""
    obs_trace.disable()
    yield
    obs_trace.disable()


# ================================================================ tracer
def test_tracer_disabled_is_noop():
    assert not obs_trace.enabled() and obs_trace.get() is None
    with obs_trace.span("x", a=1):
        pass
    obs_trace.instant("y")
    assert obs_trace.get() is None          # still nothing to record into


def test_tracer_span_and_instant_shapes():
    tr = obs_trace.enable()
    with obs_trace.span("phase", bucket=8):
        obs_trace.instant("tick", n=3)
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["i", "X"]   # span closes after
    inst, sp = evs
    assert inst["name"] == "tick" and inst["s"] == "t"
    assert inst["args"] == {"n": 3}
    assert sp["name"] == "phase" and sp["dur"] >= 0
    assert sp["args"] == {"bucket": 8}
    assert sp["ts"] <= inst["ts"]


def test_tracer_ring_drops_oldest():
    tr = obs_trace.enable(capacity=8)
    for i in range(20):
        obs_trace.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 8
    assert [e["name"] for e in evs] == [f"e{i}" for i in range(12, 20)]
    assert tr.n_recorded == 20 and tr.n_dropped == 12
    tr.clear()
    assert tr.events() == [] and tr.n_recorded == 0


def test_tracer_thread_safety():
    tr = obs_trace.enable()
    n_threads, per = 4, 500

    def work(k):
        for i in range(per):
            obs_trace.instant(f"t{k}", i=i)

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.n_recorded == n_threads * per
    assert len(tr.events()) == n_threads * per


class _FakeProgram:
    def __init__(self):
        self.n_compiles = 0
        self.n_calls = 0

    def __call__(self, x):
        self.n_calls += 1
        if self.n_calls == 1:
            self.n_compiles += 1            # "traces" on first call
        return x

    def retrace_summary(self):
        return {"causes": {"first-trace": 1}, "events": []}


def test_program_timer_passthrough_and_spans():
    inner = _FakeProgram()
    pt = obs_trace.ProgramTimer(inner, "prog")
    assert pt(7) == 7                       # disabled: pure passthrough
    assert pt.n_compiles == 1               # attribute forwarding
    assert pt.retrace_summary()["causes"] == {"first-trace": 1}

    tr = obs_trace.enable()
    assert pt(jnp.asarray(1.0)) == 1.0
    (ev,) = tr.events()
    assert ev["name"] == "prog" and ev["ph"] == "X"
    assert ev["args"]["compiled"] is False  # second call: cache hit
    assert inner.n_calls == 2


# =============================================================== metrics
def test_counter_gauge_labels():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("asks", "total asks")
    c.inc(labels={"tenant": "a"})
    c.inc(2, labels={"tenant": "a"})
    c.inc(labels={"tenant": "b"})
    assert c.value(labels={"tenant": "a"}) == 3
    assert c.value(labels={"tenant": "b"}) == 1
    assert c.value() == 0
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(5)
    g.inc(2)
    assert g.value() == 7
    with pytest.raises(TypeError):
        reg.gauge("asks")                   # name already a counter


def test_histogram_percentiles():
    h = obs_metrics.Histogram("lat_ms")
    assert h.quantile(0.5) is None          # empty series
    for v in range(1, 101):                 # 1..100 ms
        h.observe(float(v))
    p = h.percentiles()
    assert p["p50"] <= p["p95"] <= p["p99"]
    assert 25 <= p["p50"] <= 75             # bucket-resolution p50
    assert p["p99"] <= 250                  # winning bucket's bound


def test_prometheus_exposition():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("repro_asks", "asks served").inc(3, labels={"tenant": "a"})
    reg.gauge("repro_depth").set(2)
    reg.histogram("repro_lat_ms").observe(0.7)
    text = reg.render_prometheus()
    assert "# TYPE repro_asks counter" in text
    assert 'repro_asks{tenant="a"} 3' in text
    assert "repro_depth 2" in text
    assert 'repro_lat_ms_bucket{le="1"} 1' in text
    assert 'repro_lat_ms_bucket{le="+Inf"} 1' in text
    assert "repro_lat_ms_count 1" in text


# ============================================= snapshot schema (sat. 1)
def _fleet_kw(**over):
    kw = dict(n_startup_trials=4, n_restarts=4, pad_multiple=8, slots=4,
              posterior_backend="xla", refit_interval=1, warm_start=False,
              mso_options=MsoOptions(**vars(_MSO)))
    kw.update(over)
    return kw


def test_snapshot_schema_all_layers(tmp_path):
    """The four documented stats_snapshot() layouts (plus the EvalEngine
    block they compose over) match the live objects exactly — the shapes
    can't silently drift from the schema again."""
    v = obs_metrics.validate_snapshot

    assert v("eval_engine", EvalEngine(logei_acq).stats_snapshot()) == []

    ask = AskEngine(EvalEngine(logei_acq),
                    AskConfig(dim=2, n_restarts=4, pad_bucket=8,
                              refit_interval=4))
    assert v("ask_engine", ask.stats_snapshot()) == []

    fleet = FleetEngine(EvalEngine(logei_acq),
                        FleetConfig(dim=2, n_restarts=4, slots=2,
                                    pad_bucket=8))
    assert v("fleet_engine", fleet.stats_snapshot()) == []

    fs = FleetSampler(BoxSpace.cube(2, 0.0, 1.0), n_studies=1, seed=0,
                      **_fleet_kw())
    assert v("fleet_sampler", fs.stats_snapshot()) == []

    # journaled plane: the optional journal_seq key is accepted
    clock = VirtualClock()
    fsj = FleetSampler([BoxSpace.cube(2, 0.0, 1.0)], seed=0,
                       journal_dir=str(tmp_path), sleep_fn=clock.sleep,
                       **_fleet_kw())
    svc = BOService(fsj, [TenantConfig("a", studies=(0,))], clock=clock)
    r = svc.submit_ask("a", 0)
    svc.service_step()
    assert r.done
    svc.submit_tell("a", 0, r.result.trial_id, _sphere(r.result.x))
    svc.service_step()
    snap = svc.stats_snapshot()
    assert "journal_seq" in snap
    assert v("bo_service", snap) == []


def test_validate_snapshot_flags_drift():
    good = EvalEngine(logei_acq).stats_snapshot()
    bad = dict(good)
    bad.pop("n_rounds")
    bad["n_new_thing"] = 1
    errs = obs_metrics.validate_snapshot("eval_engine", bad)
    assert any("missing" in e and "n_rounds" in e for e in errs)
    assert any("unexpected" in e and "n_new_thing" in e for e in errs)
    assert obs_metrics.validate_snapshot("nope", good)


def test_ingest_snapshot_flattens_to_gauges():
    reg = obs_metrics.MetricsRegistry()
    snap = {"n_steps": 4, "queue_depth": 2,
            "retraces": {"causes": {"first-trace": 3, "shape": 1},
                         "by_program": {}},
            "svc_rung": "degrade",
            "svc_tenants": {"a": {"served": 5, "is_shed": False,
                                  "weight": 1.5}}}
    obs_metrics.ingest_snapshot(reg, "bo_service", snap,
                                labels={"study": 0})
    base = {"component": "bo_service", "study": "0"}
    assert reg.gauge("repro_n_steps").value(labels=base) == 4
    assert reg.gauge("repro_retraces").value(
        labels=dict(base, cause="shape")) == 1
    assert reg.gauge("repro_tenant_served").value(
        labels=dict(base, tenant="a")) == 5
    assert reg.gauge("repro_svc_rung_index").value(labels=base) == 2


# ====================================== retrace accounting (sat. 2)
def test_merge_retrace_reports():
    a = {"causes": {"first-trace": 2, "shape": 1},
         "by_program": {"eval": {"first-trace": 2, "shape": 1}}}
    b = {"causes": {"first-trace": 3, "dtype": 1},
         "by_program": {"full": {"first-trace": 3, "dtype": 1}}}
    m = merge_retrace_reports(a, b)
    assert m["causes"] == {"first-trace": 5, "shape": 1, "dtype": 1}
    assert set(m["by_program"]) == {"eval", "full"}
    assert m["by_program"]["full"]["dtype"] == 1
    # empty merge and identity
    assert merge_retrace_reports() == {"causes": {}, "by_program": {}}
    assert merge_retrace_reports(a)["causes"] == a["causes"]


def test_retrace_report_aggregates_programs():
    cj = CountingJit(lambda x: x * 2, name="dbl")
    for n in (2, 3):                        # two shapes -> two traces
        cj(jnp.zeros(n))
    rep = retrace_report({"dbl": cj})
    assert sum(rep["causes"].values()) == 2
    assert rep["by_program"]["dbl"] == rep["causes"]


def test_retrace_event_history_is_capped(monkeypatch):
    """retrace_events must stay bounded however often a program retraces
    (the flight recorder keeps counters exact, history truncated)."""
    import repro.engine.cache as cache_mod
    monkeypatch.setattr(cache_mod, "_MAX_EVENTS", 4)
    cj = CountingJit(lambda x: x + 1, name="grow")
    for n in range(1, 11):                  # 10 distinct shapes
        cj(jnp.zeros(n))
    assert cj.n_compiles == 10              # counter stays exact
    assert len(cj.retrace_events) == 4      # history capped
    causes = cj.retrace_summary()["causes"]
    assert sum(causes.values()) == 4


# =================================== instrumentation stays trace-free
def _tiny_sampler(seed=3):
    return GPSampler(BoxSpace.cube(2, -1.0, 1.0), strategy="dbe_vec",
                     seed=seed, n_startup_trials=4, n_restarts=4,
                     fused=True, refit_interval=4, pad_multiple=8,
                     posterior_backend="xla", mso_options=_MSO)


def test_compile_counts_identical_with_tracing_on():
    """The obs contract's hard bar: enabling the tracer changes what gets
    *measured*, never what gets *compiled*."""
    s_off = _tiny_sampler()
    s_off.optimize(_sphere, 12)
    off = s_off.stats.engine

    tr = obs_trace.enable()
    s_on = _tiny_sampler()
    s_on.optimize(_sphere, 12)
    on = s_on.stats.engine

    for k in ("n_full_compiles", "n_incr_compiles", "n_ask_compiles"):
        assert on[k] == off[k], (k, on[k], off[k])
    assert on["retraces"]["causes"] == off["retraces"]["causes"]
    names = {e["name"] for e in tr.events()}
    assert "ask.suggest" in names           # ...and the run was traced
    assert any(n.startswith("ask.phase.") or n.startswith("ask.program.")
               for n in names)


# ================================================= NaN guard (sat. 3)
def test_nan_guard_on_solo_ask_engine():
    """install_nan_guard covers the two fused AskEngine programs (not
    just the fleet plane) and is idempotent over ProgramTimer stacking."""
    ask = AskEngine(EvalEngine(logei_acq),
                    AskConfig(dim=2, n_restarts=4, pad_bucket=8,
                              refit_interval=4))
    assert nan_guard_stats(ask) == {"installed": False,
                                    "n_guard_checks": 0}
    g1 = list(install_nan_guard(ask))
    g2 = list(install_nan_guard(ask))       # idempotent re-install
    assert len(g1) == 2 and [a is b for a, b in zip(g1, g2)] == [True] * 2
    assert isinstance(ask._full_jit, FiniteGuard)
    assert nan_guard_stats(ask)["installed"]

    rng = np.random.default_rng(0)
    for _ in range(5):
        xi = rng.uniform(0, 1, 2)
        ask.observe(xi, _sphere(xi))
    ask.suggest(jax.random.PRNGKey(0), fit_seed=0)
    assert nan_guard_stats(ask)["n_guard_checks"] >= 1


def test_nan_guard_trip_reports_obs_instant():
    tr = obs_trace.enable()
    guard = FiniteGuard(lambda x: x, "full")
    with pytest.raises(NonFiniteError, match="guarded program 'full'"):
        guard(jnp.asarray([1.0, float("nan")]))
    (ev,) = [e for e in tr.events() if e["name"] == "nan_guard.nonfinite"]
    assert ev["args"]["program"] == "full"
    assert ev["args"]["direction"] == "inputs"


# ================================================== export (live + WAL)
def test_live_chrome_trace_roundtrip(tmp_path):
    obs_trace.enable()
    with obs_trace.span("ask.phase.refit", n=4):
        pass
    obs_trace.instant("retrace", program="full", cause="shape")
    events = obs_trace.get().events()
    path = str(tmp_path / "trace.json")
    obs_export.write_chrome_trace(path, events, process_name="test",
                                  meta={"bench": "test"})
    with open(path) as f:
        obj = json.load(f)
    assert obs_export.validate_chrome_trace(obj) == []
    assert obj["displayTimeUnit"] == "ms"
    assert obj["otherData"] == {"bench": "test"}
    names = [e["name"] for e in obj["traceEvents"]]
    assert "process_name" in names          # pid metadata present
    assert "ask.phase.refit" in names and "retrace" in names


def test_validate_chrome_trace_rejects_malformed():
    assert obs_export.validate_chrome_trace([]) \
        == ["top level is list, expected object"]
    assert obs_export.validate_chrome_trace({}) \
        == ["traceEvents missing or not a list"]
    errs = obs_export.validate_chrome_trace({"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0},
        {"ph": "i", "pid": "x", "tid": 1, "ts": 0.0, "args": 3},
    ]})
    assert any("dur" in e for e in errs)
    assert any("'name'" in e for e in errs)
    assert any("integer 'pid'" in e for e in errs)
    assert any("'args'" in e for e in errs)


def test_phase_breakdown():
    evs = [{"name": "a", "ph": "X", "ts": 0, "dur": 1000.0},
           {"name": "a", "ph": "X", "ts": 0, "dur": 3000.0},
           {"name": "b", "ph": "X", "ts": 0, "dur": 500.0},
           {"name": "c", "ph": "i", "ts": 0}]
    bd = obs_export.phase_breakdown(evs)
    assert set(bd) == {"a", "b"}            # instants excluded
    assert bd["a"]["count"] == 2 and bd["a"]["total_ms"] == 4.0
    assert bd["a"]["p50_ms"] == 2.0         # linear interp between 1, 3
    assert bd["b"]["p99_ms"] == 0.5


def _journaled_service(tmp_path):
    clock = VirtualClock()
    fs = FleetSampler([BoxSpace.cube(2, 0.0, 1.0)] * 2, seed=0,
                      journal_dir=str(tmp_path), sleep_fn=clock.sleep,
                      **_fleet_kw())
    svc = BOService(fs, [TenantConfig("a", studies=(0,)),
                         TenantConfig("b", studies=(1,))], clock=clock)
    return svc, clock


def test_timeline_from_journal(tmp_path):
    """WAL → Perfetto reconstruction: valid Chrome trace with request
    lifecycle spans on tenant tracks and fleet ops on study tracks —
    with tracing off (the post-mortem path needs no live tracer)."""
    svc, _ = _journaled_service(tmp_path)
    reqs = [svc.submit_ask(t, s) for t, s in (("a", 0), ("b", 1))]
    for _ in range(4):
        svc.service_step()
    assert all(r.done for r in reqs)
    for r in reqs:
        svc.submit_tell(r.tenant, r.study, r.result.trial_id,
                        _sphere(r.result.x))
    svc.service_step()
    inflight = svc.submit_ask("a", 0)       # left open: crash-visible
    assert not inflight.done

    trace = obs_export.timeline_from_journal(str(tmp_path))
    assert obs_export.validate_chrome_trace(trace) == []
    assert trace["otherData"]["source"] == "wal-journal"
    assert trace["otherData"]["n_records"] > 0

    evs = trace["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    done = [e for e in spans if e["name"] == "request"]
    assert len(done) == 2                   # one lifecycle span per ask
    assert {e["args"]["tenant"] for e in done} == {"a", "b"}
    open_spans = [e for e in spans if e["name"] == "request(inflight)"]
    assert len(open_spans) == 1 and open_spans[0]["args"]["open"]
    # both planes present, with named tracks
    pids = {e["pid"] for e in evs}
    assert {obs_export.FLEET_PID, obs_export.SVC_PID} <= pids
    tnames = [e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"]
    assert "tenant a" in tnames and "scheduler" in tnames


def test_obs_cli_timeline_and_validate(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main

    svc, _ = _journaled_service(tmp_path)
    r = svc.submit_ask("a", 0)
    svc.service_step()
    assert r.done

    out = str(tmp_path / "timeline.json")
    assert obs_main(["timeline", str(tmp_path), "-o", out]) == 0
    assert obs_main(["validate", out]) == 0
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"traceEvents": [{"oops": 1}]}, f)
    assert obs_main(["validate", bad]) == 1
    capsys.readouterr()


def test_obs_cli_overhead_budget():
    from repro.obs.__main__ import main as obs_main

    assert obs_main(["overhead", "--n", "20000"]) == 0
    # an impossible budget must fail loudly, not silently pass
    assert obs_main(["overhead", "--n", "2000",
                     "--budget-ns", "0.0001"]) == 1
