"""Box search space with unit-cube normalization (GPSampler convention:
the GP and the acquisition optimization always live on [0, 1]^D)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BoxSpace:
    lower: np.ndarray      # (D,)
    upper: np.ndarray      # (D,)

    def __post_init__(self):
        object.__setattr__(self, "lower", np.asarray(self.lower, np.float64))
        object.__setattr__(self, "upper", np.asarray(self.upper, np.float64))
        if self.lower.shape != self.upper.shape:
            raise ValueError("bound shapes differ")
        if np.any(self.lower >= self.upper):
            raise ValueError("lower must be < upper elementwise")

    @property
    def dim(self) -> int:
        return self.lower.shape[0]

    @classmethod
    def cube(cls, dim: int, lo: float, hi: float) -> "BoxSpace":
        return cls(np.full(dim, lo), np.full(dim, hi))

    def to_unit(self, x: np.ndarray) -> np.ndarray:
        return (x - self.lower) / (self.upper - self.lower)

    def from_unit(self, u: np.ndarray) -> np.ndarray:
        return self.lower + u * (self.upper - self.lower)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.uniform(self.lower, self.upper, (n, self.dim))
