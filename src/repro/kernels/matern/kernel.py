"""Matérn-5/2 gram matrix as a Pallas TPU kernel.

Hot spot: the O(n²D) gram construction inside every GP fit step (the fit's
L-BFGS-B evaluates the marginal likelihood dozens of times) and the (q, n)
cross-gram inside every batched acquisition evaluation — the cost the
paper's §4 model says dominates MSO.

TPU mapping: tiles of (TILE_M, TILE_N) outputs are produced per grid step;
each step loads an (TILE_M, D) and (TILE_N, D) slab of pre-scaled points
into VMEM and forms -2·a·bᵀ on the MXU, then applies the Matérn polynomial
on the VPU.  D is kept whole per block (BO dims are small); M/N tiles are
128-aligned for lane efficiency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 2.2360679774997896

TILE_M = 128
TILE_N = 128


def _matern_kernel(a_ref, b_ref, asq_ref, bsq_ref, amp_ref, out_ref):
    """One (TILE_M, TILE_N) block of the gram matrix.

    a_ref: (TILE_M, D) pre-scaled rows; b_ref: (TILE_N, D);
    asq_ref/bsq_ref: (TILE_M, 1)/(TILE_N, 1) squared norms; amp_ref: (1, 1).
    """
    a = a_ref[...]
    b = b_ref[...]
    # MXU: (M, D) @ (D, N)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = asq_ref[...] + bsq_ref[...].T - 2.0 * ab
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-36)
    poly = 1.0 + SQRT5 * r + (5.0 / 3.0) * d2
    out_ref[...] = (amp_ref[0, 0] * poly * jnp.exp(-SQRT5 * r)
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matern52_gram(x1: jax.Array, x2: jax.Array, inv_lengthscale: jax.Array,
                  amplitude: jax.Array, *, interpret: bool = False
                  ) -> jax.Array:
    """Pallas Matérn-5/2 cross gram, padded to tile multiples.

    Returns (n1, n2) in x1.dtype.  Use ``interpret=True`` off-TPU.
    """
    n1, d = x1.shape
    n2 = x2.shape[0]
    dtype = x1.dtype

    a = (x1 * inv_lengthscale).astype(jnp.float32)
    b = (x2 * inv_lengthscale).astype(jnp.float32)

    m_pad = (-n1) % TILE_M
    n_pad = (-n2) % TILE_N
    a = jnp.pad(a, ((0, m_pad), (0, 0)))
    b = jnp.pad(b, ((0, n_pad), (0, 0)))
    asq = jnp.sum(a * a, -1, keepdims=True)                 # (M, 1)
    bsq = jnp.sum(b * b, -1, keepdims=True)                 # (N, 1)
    amp = jnp.asarray(amplitude, jnp.float32).reshape(1, 1)

    M, N = a.shape[0], b.shape[0]
    grid = (M // TILE_M, N // TILE_N)

    out = pl.pallas_call(
        _matern_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, d), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_M, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b, asq, bsq, amp)

    return out[:n1, :n2].astype(dtype)


# ---------------------------------------------------------------------------
# fused posterior: cross-gram + mean/variance epilogue
# ---------------------------------------------------------------------------

VAR_FLOOR = 1e-16           # matches gpr.predict's variance clamp

MAX_TRAIN = 2048            # K⁻¹ (N², f32) must fit VMEM alongside the tile


def _posterior_kernel(a_ref, b_ref, asq_ref, bsq_ref, alpha_ref, kinv_ref,
                      amp_ref, mean_ref, var_ref):
    """One (TILE_Q,) slab of posterior mean/variance.

    a_ref: (TILE_Q, D) pre-scaled queries; b_ref: (N, D) the WHOLE
    pre-scaled training set (BO training sets are small — N ≤ MAX_TRAIN —
    so K⁻¹ fits VMEM and the cross-gram row never round-trips to HBM);
    alpha_ref: (N, 1) K⁻¹y; kinv_ref: (N, N).

    The (TILE_Q, N) cross-gram slab is built once on MXU+VPU and feeds
    both epilogues in-register:
      mean = K α                (MXU, (TILE_Q, 1))
      var  = σ_f² − rowsum((K K⁻¹) ∘ K)   (MXU + VPU)
    """
    a = a_ref[...]
    b = b_ref[...]
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = asq_ref[...] + bsq_ref[...].T - 2.0 * ab
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-36)
    k = amp_ref[0, 0] * (1.0 + SQRT5 * r + (5.0 / 3.0) * d2) * \
        jnp.exp(-SQRT5 * r)                                  # (TILE_Q, N)

    mean_ref[...] = k @ alpha_ref[...]                        # (TILE_Q, 1)
    t = jax.lax.dot_general(k, kinv_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    quad = jnp.sum(t * k, axis=-1, keepdims=True)             # (TILE_Q, 1)
    var_ref[...] = jnp.maximum(amp_ref[0, 0] - quad, VAR_FLOOR)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matern52_posterior(xq: jax.Array, xt: jax.Array, alpha: jax.Array,
                       kinv: jax.Array, inv_lengthscale: jax.Array,
                       amplitude: jax.Array, *, interpret: bool = False):
    """Pallas-fused GP posterior: ((q,) mean, (q,) variance).

    Forward-only (see ``ops.matern52_posterior_op`` for the differentiable
    wrapper).  Queries are padded to TILE_M multiples; training rows to
    TILE_N multiples with zero-padded α and K⁻¹ (padded rows therefore
    contribute exactly nothing to either epilogue).
    """
    nq, d = xq.shape
    nt = xt.shape[0]
    if nt > MAX_TRAIN:
        raise ValueError(
            f"fused posterior holds K⁻¹ in VMEM; n={nt} exceeds "
            f"MAX_TRAIN={MAX_TRAIN} — use the xla backend")
    dtype = xq.dtype

    a = (xq * inv_lengthscale).astype(jnp.float32)
    b = (xt * inv_lengthscale).astype(jnp.float32)
    q_pad = (-nq) % TILE_M
    n_pad = (-nt) % TILE_N
    a = jnp.pad(a, ((0, q_pad), (0, 0)))
    b = jnp.pad(b, ((0, n_pad), (0, 0)))
    al = jnp.pad(alpha.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    ki = jnp.pad(kinv.astype(jnp.float32), ((0, n_pad), (0, n_pad)))
    asq = jnp.sum(a * a, -1, keepdims=True)
    bsq = jnp.sum(b * b, -1, keepdims=True)
    amp = jnp.asarray(amplitude, jnp.float32).reshape(1, 1)

    Q, N = a.shape[0], b.shape[0]
    grid = (Q // TILE_M,)

    mean, var = pl.pallas_call(
        _posterior_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, d), lambda i: (i, 0)),
            pl.BlockSpec((N, d), lambda i: (0, 0)),
            pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
            pl.BlockSpec((N, N), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
            pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, 1), jnp.float32),
            jax.ShapeDtypeStruct((Q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, asq, bsq, al, ki, amp)

    return mean[:nq, 0].astype(dtype), var[:nq, 0].astype(dtype)
