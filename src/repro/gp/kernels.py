"""GP covariance kernels (Matérn-5/2 with ARD, RBF) — pure jnp reference.

The Pallas-tiled TPU implementations live in ``repro.kernels.matern``; these
jnp versions are both the oracle for those kernels and the CPU execution
path for the BO benchmarks.  The paper's GPSampler setting is Matérn-ν=5/2.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

SQRT5 = 2.2360679774997896


class KernelParams(NamedTuple):
    """Log-parameterized (unconstrained) ARD kernel hyperparameters."""
    log_lengthscale: Array   # (D,)
    log_amplitude: Array     # ()  log σ_f²  (variance, not std)
    log_noise: Array         # ()  log σ_n²

    @property
    def lengthscale(self):
        return jnp.exp(self.log_lengthscale)

    @property
    def amplitude(self):
        return jnp.exp(self.log_amplitude)

    @property
    def noise(self):
        return jnp.exp(self.log_noise)


def init_params(dim: int, dtype=jnp.float64) -> KernelParams:
    return KernelParams(
        log_lengthscale=jnp.zeros((dim,), dtype),
        log_amplitude=jnp.zeros((), dtype),
        log_noise=jnp.asarray(-4.0, dtype),   # exp(-4) ≈ 1.8e-2
    )


def _sq_dists(x1: Array, x2: Array, inv_ls: Array) -> Array:
    """Scaled squared distances, (n1, n2). Numerically clamped at 0."""
    a = x1 * inv_ls
    b = x2 * inv_ls
    # ||a-b||^2 = |a|^2 + |b|^2 - 2ab ; clamp negatives from cancellation
    d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
          - 2.0 * (a @ b.T))
    return jnp.maximum(d2, 0.0)


def matern52(x1: Array, x2: Array, params: KernelParams) -> Array:
    """Matérn-5/2 cross covariance, (n1, n2).

    k(r) = σ_f² (1 + √5 r + 5r²/3) exp(-√5 r),  r = ||(x−x')/ℓ||.
    """
    inv_ls = jnp.exp(-params.log_lengthscale)
    d2 = _sq_dists(x1, x2, inv_ls)
    r = jnp.sqrt(d2 + 1e-36)          # eps keeps the gradient finite at r=0
    poly = 1.0 + SQRT5 * r + (5.0 / 3.0) * d2
    return params.amplitude * poly * jnp.exp(-SQRT5 * r)


def rbf(x1: Array, x2: Array, params: KernelParams) -> Array:
    inv_ls = jnp.exp(-params.log_lengthscale)
    d2 = _sq_dists(x1, x2, inv_ls)
    return params.amplitude * jnp.exp(-0.5 * d2)


KERNELS = {"matern52": matern52, "rbf": rbf}


def gram(x: Array, params: KernelParams, kernel: str = "matern52",
         jitter: float = 1e-8) -> Array:
    """Training gram matrix with noise + jitter on the diagonal."""
    k = KERNELS[kernel](x, x, params)
    n = x.shape[0]
    return k + (params.noise + jitter) * jnp.eye(n, dtype=k.dtype)
