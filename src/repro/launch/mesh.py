"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run pins the device count before any
jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips; the
    "pod" axis extends data parallelism across the cross-pod DCN/ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny host-device mesh for tests (requires
    --xla_force_host_platform_device_count >= prod(shape))."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s/link
HBM_BYTES = 16 * 1024**3        # 16 GiB
