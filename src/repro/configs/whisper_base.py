"""whisper-base [audio]: enc-dec, conv frontend STUBBED (precomputed frame
embeddings).  6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    n_enc_layers=6, n_dec_layers=6, enc_seq_fraction=0.5,
    frontend="audio_frames",
    norm="layernorm", activation="gelu", rope_fraction=0.0,
    sub_quadratic=False,
)
