"""Mesh-sharded fleet plane tests.  Multi-device cases run in
SUBPROCESSES (``run_sub`` conftest fixture) with virtual host devices so
the device-count flag never leaks into the rest of the suite.

Invariants pinned here:
  * device-placement independence — the same fleet driven on a 1-device
    and an 8-device mesh produces bit-for-bit identical trajectories with
    identical compile counts (``cfg.slots`` is the PER-DEVICE width, so
    every device runs the same fixed-width local program);
  * cross-device migration exactness — a study that outgrows its bucket
    on one device and is re-admitted on another tracks the solo AskEngine
    trajectory to <=1e-10 and takes the full-refit program on its first
    post-migration suggest.
"""


def test_fleet_placement_independence_bitwise(run_sub):
    out = run_sub("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.bo.sampler import FleetSampler
        from repro.bo.space import BoxSpace
        from repro.core.mso import MsoOptions
        from repro.launch.mesh import make_fleet_mesh

        def sphere(x):
            return float(np.sum((x - 0.4) ** 2))

        kw = dict(n_startup_trials=4, n_restarts=4, pad_multiple=8,
                  posterior_backend="xla", refit_interval=4,
                  mso_options=MsoOptions(maxiter=40, pgtol=1e-2))

        def drive(mesh):
            fs = FleetSampler(BoxSpace.cube(2, -1.0, 1.0), n_studies=8,
                              seed=5, slots=2, mesh=mesh, **kw)
            xs = []
            for _ in range(10):
                trials = fs.ask_all()
                xs.append(np.stack([t.x for t in trials]))
                for s, t in enumerate(trials):
                    fs.tell(s, t.trial_id, sphere(t.x))
            return np.stack(xs), fs.stats_snapshot()

        x1, s1 = drive(make_fleet_mesh(1))
        x8, s8 = drive(make_fleet_mesh(8))
        assert np.array_equal(x1, x8), np.max(np.abs(x1 - x8))
        assert s1["n_fleet_compiles"] == s8["n_fleet_compiles"], (s1, s8)
        assert s8["n_devices"] == 8
        assert s8["slots_per_device"] == [1] * 8, s8["slots_per_device"]
        assert s8["n_migrations"] == 8          # every study crossed b=8
        print("PLACEMENT_OK", s1["n_fleet_compiles"],
              s8["n_migrations_intra"], s8["n_migrations_cross"])
    """, devices=8, timeout=600)
    assert "PLACEMENT_OK" in out


def test_fleet_cross_device_migration_matches_askengine(run_sub):
    """Bucket growth that lands a study on a DIFFERENT device (evict on
    device 0, re-admit on device 1) is exact: <=1e-10 vs the solo fused
    AskEngine, full program on the first post-migration suggest."""
    out = run_sub("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.core.acquisition import logei_acq
        from repro.core.lbfgsb import LbfgsbOptions
        from repro.engine import EvalEngine, FleetConfig, FleetEngine
        from repro.engine.ask import AskConfig, AskEngine
        from repro.launch.mesh import make_fleet_mesh

        def f(x):
            return float(np.sum((x - 0.4) ** 2))

        kw = dict(dim=2, n_restarts=4, pad_bucket=8, refit_interval=6,
                  warm_start=True, gp_fit_restarts=2,
                  mso=LbfgsbOptions(m=10, maxiter=40, pgtol=1e-2,
                                    ftol=0.0, maxls=25))
        # 2 global slots, 1 per device: admission order pins placement.
        fleet = FleetEngine(EvalEngine(logei_acq),
                            FleetConfig(slots=1, **kw),
                            mesh=make_fleet_mesh(2))
        ref = AskEngine(EvalEngine(logei_acq), AskConfig(**kw))

        rng = np.random.default_rng(0)
        obs = {sid: rng.uniform(0, 1, (n, 2))
               for sid, n in (("D", 9), ("E", 4), ("A", 4))}
        for sid in ("D", "E", "A"):
            fleet.add_study(sid)
            for x in obs[sid]:
                fleet.observe(sid, x, f(x))
        for x in obs["A"]:
            ref.observe(x, f(x))
        # balanced admission: D (bucket 16) -> device 0; E (bucket 8) ->
        # device 1 (less loaded); A (bucket 8) -> the remaining device-0
        # slot.  E then idles; A grows 4 -> 9 and must re-admit into the
        # free bucket-16 slot on device 1 — a cross-device migration.
        seed_of = {"D": 0, "A": 2}
        kinds = []
        for t in range(7):
            for sid in ("D", "A"):
                fleet.request_suggest(
                    sid, jax.random.fold_in(
                        jax.random.PRNGKey(100 + seed_of[sid]), t),
                    fit_seed=t)
            fleet.step()
            for sid in ("D", "A"):
                x, info = fleet.pop_result(sid)
                if sid == "A":
                    xr, info_r = ref.suggest(jax.random.fold_in(
                        jax.random.PRNGKey(102), t), fit_seed=t)
                    err = float(np.max(np.abs(x - xr)))
                    assert err <= 1e-10, (t, err)
                    assert info.kind == info_r.kind, (t, info.kind,
                                                      info_r.kind)
                    kinds.append(info.kind)
                    xo = np.clip(x, 0, 1)
                    ref.observe(xo, f(xo))     # same trajectory as fleet
                xo = np.clip(x, 0, 1)
                fleet.observe(sid, xo, f(xo))

        snap = fleet.stats_snapshot()
        # A outgrew bucket 8 after round 4 (n: 4 -> 9); round 5 is its
        # first post-migration suggest and must take the full program
        assert kinds[5] == "full", kinds
        assert snap["n_migrations"] == 1, snap
        assert snap["n_migrations_cross"] == 1, snap
        assert snap["n_migrations_intra"] == 0, snap
        assert snap["slots_per_device"] == [1, 2], snap
        print("CROSS_MIGRATION_OK", kinds)
    """, devices=2, timeout=600)
    assert "CROSS_MIGRATION_OK" in out
