"""chameleon-34b [vlm]: early-fusion, VQ image tokens live in the vocab.
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536, qk-norm.
[arXiv:2405.09818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    qk_norm=True, norm="rmsnorm", activation="swiglu",
    rope_theta=10000.0, frontend="vq_image",
    sub_quadratic=False,
)
