"""Runtime sanitizers: the opt-in NaN guard (``--debug-nans``).

The static ``nan-hazard`` rule proves no *syntactic* path feeds a
non-finite value into a shared carry; this guard proves the actual
``_FAR`` benign-row invariant at runtime — every float leaf entering or
leaving a guarded program is finite, idle and quarantined rows included.
It costs one host sync per program call, so it is strictly opt-in (chaos
benches, debugging), never the hot path.

Guarded planes: the three fleet block programs (full refit, incremental
refit, MSO tail) and the two solo AskEngine programs (fused full /
incremental ask) — :func:`install_nan_guard` picks the set from the
engine's attributes.  A tripped guard reports through the obs plane
(an ``nan_guard.nonfinite`` instant on the flight-recorder timeline)
before raising, so a crashed chaos run shows *where* the poison crossed
a program boundary.
"""
from __future__ import annotations

from typing import Any, Iterable, Tuple

import jax
import jax.numpy as jnp

from repro.obs.trace import instant as _obs_instant


class NonFiniteError(AssertionError):
    """A float leaf crossing a guarded program boundary was NaN/Inf."""


def _first_nonfinite(tree: Any) -> Tuple[str, Any]:
    """(path, leaf) of the first non-finite float leaf, or ("", None)."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    for path, leaf in leaves:
        dt = getattr(leaf, "dtype", None)
        if dt is None or not jnp.issubdtype(dt, jnp.floating):
            continue
        if not bool(jnp.all(jnp.isfinite(leaf))):
            return jax.tree_util.keystr(path), leaf
    return "", None


class FiniteGuard:
    """Wrap a CountingJit-like callable with finite-checks on every
    float input and output leaf.  All other attributes (``n_compiles``,
    ``retrace_summary`` …) pass through, so engine snapshots keep
    working on the guarded program."""

    def __init__(self, inner, label: str):
        self._inner = inner
        self._label = label
        self.n_guard_checks = 0

    def _check(self, tree: Any, direction: str) -> None:
        path, leaf = _first_nonfinite(tree)
        if leaf is not None:
            _obs_instant("nan_guard.nonfinite", program=self._label,
                         direction=direction, leaf=path or "<root>")
            raise NonFiniteError(
                f"non-finite value in {direction} of guarded program "
                f"'{self._label}' at leaf {path or '<root>'} "
                f"(shape {getattr(leaf, 'shape', '?')}): the _FAR "
                f"benign-row invariant is violated — an idle/quarantined "
                f"slot leaked NaN/Inf into the shared carry")

    def __call__(self, *args: Any, **kwargs: Any):
        self.n_guard_checks += 1
        self._check((args, kwargs), "inputs")
        out = self._inner(*args, **kwargs)
        self._check(out, "outputs")
        return out

    def __getattr__(self, name: str):
        return getattr(self._inner, name)


_FLEET_PROGRAMS = ("_full_jit", "_incr_jit", "_mso_jit")
_ASK_PROGRAMS = ("_full_jit", "_incr_jit")


def _program_attrs(engine) -> Tuple[str, ...]:
    """Which jitted-program attributes an engine exposes: the fleet
    plane carries a separate MSO tail program, the solo AskEngine fuses
    it into its two programs."""
    return _FLEET_PROGRAMS if hasattr(engine, "_mso_jit") \
        else _ASK_PROGRAMS


def install_nan_guard(engine) -> Iterable[FiniteGuard]:
    """Wrap an engine's jitted programs in place — the three fleet block
    programs or the two solo AskEngine programs.  Returns the guards
    (idempotent: re-installing over an existing guard is a no-op)."""
    guards = []
    for attr in _program_attrs(engine):
        prog = getattr(engine, attr)
        if isinstance(prog, FiniteGuard):
            guards.append(prog)
            continue
        g = FiniteGuard(prog, attr.strip("_").replace("_jit", ""))
        setattr(engine, attr, g)
        guards.append(g)
    return guards


def nan_guard_stats(engine) -> dict:
    """``{"installed": bool, "n_guard_checks": int}`` for summaries."""
    progs = [getattr(engine, a, None) for a in _program_attrs(engine)]
    installed = all(isinstance(p, FiniteGuard) for p in progs)
    return {"installed": installed,
            "n_guard_checks": sum(p.n_guard_checks for p in progs
                                  if isinstance(p, FiniteGuard))}
