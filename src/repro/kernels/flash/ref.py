"""Pure-jnp oracle for (optionally causal / local-windowed) attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (Sq, H), k/v: (Sk, H) — single head; vmap for batch/heads.

    ``window``: local attention — query i sees keys in (i-window, i].
    """
    sq, h = q.shape
    sk = k.shape[0]
    scale = (h ** -0.5) if scale is None else scale
    logits = (q @ k.T) * scale                                # (Sq, Sk)
    iq = jnp.arange(sq)[:, None] + (sk - sq)                  # absolute q pos
    ik = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= ik <= iq
    if window is not None:
        mask &= ik > iq - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)     # fully-masked rows
    return p @ v
