"""Paper-faithful coroutine D-BE on top of *unmodified* scipy L-BFGS-B.

The paper (§4, "Decouple L-BFGS-B Updates by Coroutine") realizes D-BE with
one *batch evaluator* plus ``B`` *worker* coroutines, each a suspended
L-BFGS-B solver.  scipy's public ``minimize`` offers no per-iteration hook,
but its reverse-communication core ``_lbfgsb.setulb`` is exactly a coroutine:
it returns to the caller whenever it needs ``(f, g)`` at a point and resumes
from the same internal state.  We wrap each solver instance in a Python
generator (``lbfgsb_worker``) that *yields* evaluation requests and
*receives* results — cooperative multitasking as in the paper — and drive all
workers round-by-round with one batched JAX evaluation per round.

Task codes of scipy>=1.15's C ``setulb`` (verified empirically):
  3 = FG   (evaluate objective+gradient at ``x``)
  1 = NEW_X (one QN iteration finished)
  2/4 = converged, 5 = user stop, anything else = error/stop.

scipy<1.15 ships the original Fortran ``setulb`` whose task channel is a
60-char string ('FG...', 'NEW_X', 'CONV...'); ``_SetulbDriver`` adapts both
APIs to the integer codes above so the worker logic is version-agnostic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Generator, List, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import _lbfgsb

_TASK_FG = 3
_TASK_NEW_X = 1
_TASK_CONV = 2
_TASK_STOP = 5
_TASK_ERROR = 99

# scipy>=1.15 rewrote setulb in C with integer task codes and no
# iprint/csave; detect which ABI this interpreter has once at import.
_HAS_C_SETULB = "iprint" not in (_lbfgsb.setulb.__doc__ or "iprint")


class _SetulbDriver:
    """Reverse-communication L-BFGS-B adapted to one integer task code.

    Owns the solver workspace for one restart; ``step()`` advances the
    underlying ``setulb`` once and returns one of the ``_TASK_*`` codes.
    ``x``/``f``/``g`` are the live in/out buffers (f and g must be written
    by the caller before the step that follows a ``_TASK_FG``).
    """

    def __init__(self, x0, low, up, nbd, m, factr, pgtol, maxls):
        n = x0.size
        self.m, self.factr, self.pgtol, self.maxls = m, factr, pgtol, maxls
        self.x = x0
        self.f = np.array(0.0, np.float64)
        self.g = np.zeros(n, np.float64)
        self.low, self.up, self.nbd = low, up, nbd
        self.wa = np.zeros(2 * m * n + 5 * n + 11 * m * m + 8 * m,
                           np.float64)
        self.iwa = np.zeros(3 * n, np.int32)
        self.lsave = np.zeros(4, np.int32)
        self.isave = np.zeros(44, np.int32)
        self.dsave = np.zeros(29, np.float64)
        if _HAS_C_SETULB:
            self.task = np.zeros(2, np.int32)
            self.ln_task = np.zeros(2, np.int32)
        else:
            self.task = np.zeros(1, "S60")
            self.task[:] = b"START"
            self.csave = np.zeros(1, "S60")

    def step(self) -> int:
        if _HAS_C_SETULB:
            _lbfgsb.setulb(self.m, self.x, self.low, self.up, self.nbd,
                           self.f, self.g, self.factr, self.pgtol, self.wa,
                           self.iwa, self.task, self.lsave, self.isave,
                           self.dsave, self.maxls, self.ln_task)
            t = int(self.task[0])
            if t in (_TASK_FG, _TASK_NEW_X, _TASK_CONV, 4, _TASK_STOP):
                return _TASK_CONV if t == 4 else t
            return _TASK_ERROR
        _lbfgsb.setulb(self.m, self.x, self.low, self.up, self.nbd,
                       self.f, self.g, self.factr, self.pgtol, self.wa,
                       self.iwa, self.task, -1, self.csave, self.lsave,
                       self.isave, self.dsave, self.maxls)
        t = self.task.tobytes()
        if t.startswith(b"FG"):
            return _TASK_FG
        if t.startswith(b"NEW_X"):
            return _TASK_NEW_X
        if t.startswith(b"CONV"):
            return _TASK_CONV
        if t.startswith(b"STOP"):
            return _TASK_STOP
        return _TASK_ERROR

EvalRequest = np.ndarray          # the point the worker wants evaluated
EvalResult = Tuple[float, np.ndarray]


@dataclass
class WorkerStats:
    n_iters: int = 0              # L-BFGS-B iterations (NEW_X events)
    n_evals: int = 0              # objective/gradient evaluations
    status: str = "running"
    x: Optional[np.ndarray] = None
    f: float = np.inf


def lbfgsb_worker(
    x0: np.ndarray,
    lower: np.ndarray,
    upper: np.ndarray,
    *,
    m: int = 10,
    maxiter: int = 200,
    pgtol: float = 1e-5,
    factr: float = 0.0,
    maxls: int = 25,
    stats: Optional[WorkerStats] = None,
) -> Generator[EvalRequest, EvalResult, WorkerStats]:
    """One restart as a coroutine: ``yield x`` → receive ``(f, g)``.

    The underlying solver is scipy's L-BFGS-B, unmodified; this generator is
    the paper's "worker".  It terminates (StopIteration) when the solver
    converges or hits ``maxiter``; ``stats`` carries the outcome.
    """
    n = x0.size
    st = stats if stats is not None else WorkerStats()
    x = np.clip(np.asarray(x0, np.float64).copy(), lower, upper)
    nbd = np.full(n, 2, np.int32)          # both-sided bounds (BO boxes)
    low = np.ascontiguousarray(
        np.broadcast_to(np.asarray(lower, np.float64), (n,)))
    up = np.ascontiguousarray(
        np.broadcast_to(np.asarray(upper, np.float64), (n,)))
    drv = _SetulbDriver(x, low, up, nbd, m, factr, pgtol, maxls)

    while True:
        t = drv.step()
        if t == _TASK_FG:
            fv, gv = yield x              # suspend; evaluator resumes us
            drv.f = np.array(fv, np.float64)
            # hard copy: gv may be a read-only view of a device buffer,
            # but setulb takes g as intent(inout)
            drv.g = np.array(gv, np.float64, copy=True)
            st.n_evals += 1
        elif t == _TASK_NEW_X:
            st.n_iters += 1
            if st.n_iters >= maxiter:
                st.status = "maxiter"
                break
        else:
            st.status = "converged" if t == _TASK_CONV else f"stop({t})"
            break
    st.x = x.copy()
    st.f = float(drv.f)
    return st


BatchEvalFn = Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]]
# (k, D) -> ((k,) f, (k, D) g)


@dataclass
class MultistartOutcome:
    x: np.ndarray                 # (B, D) final per-restart points
    f: np.ndarray                 # (B,)   final per-restart values (min scale)
    n_iters: np.ndarray           # (B,)
    n_evals: np.ndarray           # (B,)   per-restart objective evals
    n_rounds: int                 # batched evaluation rounds
    batch_sizes: List[int] = field(default_factory=list)
    wall_time: float = 0.0


def run_dbe_coroutine(
    batch_eval: BatchEvalFn,
    x0: np.ndarray,
    lower: np.ndarray,
    upper: np.ndarray,
    **worker_opts,
) -> MultistartOutcome:
    """D-BE: decoupled per-restart QN updates, batched evaluations.

    Algorithm 1's right column.  Maintains the active set A of ongoing
    restarts; converged workers are pruned so the evaluation batch shrinks
    progressively (paper §4).
    """
    t0 = time.perf_counter()
    B, D = x0.shape
    stats = [WorkerStats() for _ in range(B)]
    workers: List[Optional[Generator]] = []
    pending: List[Optional[np.ndarray]] = []
    for b in range(B):
        w = lbfgsb_worker(x0[b], lower, upper, stats=stats[b], **worker_opts)
        try:
            req = next(w)                 # prime: first FG request
            workers.append(w)
            pending.append(req.copy())
        except StopIteration:
            workers.append(None)
            pending.append(None)

    n_rounds = 0
    batch_sizes: List[int] = []
    while True:
        active = [b for b in range(B) if workers[b] is not None]
        if not active:
            break
        X = np.stack([pending[b] for b in active])       # (|A|, D)
        fs, gs = batch_eval(X)                           # one batched call
        n_rounds += 1
        batch_sizes.append(len(active))
        for i, b in enumerate(active):
            try:
                req = workers[b].send((float(fs[i]), np.asarray(gs[i])))
                pending[b] = req.copy()
            except StopIteration:
                workers[b] = None
                pending[b] = None

    return MultistartOutcome(
        x=np.stack([s.x for s in stats]),
        f=np.array([s.f for s in stats]),
        n_iters=np.array([s.n_iters for s in stats]),
        n_evals=np.array([s.n_evals for s in stats]),
        n_rounds=n_rounds,
        batch_sizes=batch_sizes,
        wall_time=time.perf_counter() - t0,
    )


def run_seq_opt(
    batch_eval: BatchEvalFn,
    x0: np.ndarray,
    lower: np.ndarray,
    upper: np.ndarray,
    **worker_opts,
) -> MultistartOutcome:
    """SEQ. OPT. (Algorithm 2): restarts one after another, no batching.

    Evaluations go through the same ``batch_eval`` with k=1, so the only
    difference from D-BE is the absence of cross-restart batching — exactly
    the paper's control condition.
    """
    t0 = time.perf_counter()
    B, D = x0.shape
    stats = [WorkerStats() for _ in range(B)]
    n_rounds = 0
    for b in range(B):
        w = lbfgsb_worker(x0[b], lower, upper, stats=stats[b], **worker_opts)
        try:
            req = next(w)
            while True:
                fs, gs = batch_eval(req[None, :])
                n_rounds += 1
                req = w.send((float(fs[0]), np.asarray(gs[0])))
        except StopIteration:
            pass
    return MultistartOutcome(
        x=np.stack([s.x for s in stats]),
        f=np.array([s.f for s in stats]),
        n_iters=np.array([s.n_iters for s in stats]),
        n_evals=np.array([s.n_evals for s in stats]),
        n_rounds=n_rounds,
        batch_sizes=[1] * n_rounds,
        wall_time=time.perf_counter() - t0,
    )


def run_cbe(
    batch_eval: BatchEvalFn,
    x0: np.ndarray,
    lower: np.ndarray,
    upper: np.ndarray,
    **worker_opts,
) -> MultistartOutcome:
    """C-BE (BoTorch ≤0.14): ONE L-BFGS-B over the flattened (B·D,) vector
    minimizing ``Σ_b f(x^(b))``.  The shared dense QN state over B·D dims is
    what produces the off-diagonal artifacts."""
    t0 = time.perf_counter()
    B, D = x0.shape
    st = WorkerStats()
    lo = np.broadcast_to(lower, (B, D)).reshape(-1)
    hi = np.broadcast_to(upper, (B, D)).reshape(-1)
    w = lbfgsb_worker(x0.reshape(-1), lo, hi, stats=st, **worker_opts)
    n_rounds = 0
    try:
        req = next(w)
        while True:
            X = req.reshape(B, D)
            fs, gs = batch_eval(X)                       # batched under the hood
            n_rounds += 1
            req = w.send((float(np.sum(fs)), np.asarray(gs).reshape(-1)))
    except StopIteration:
        pass
    Xf = st.x.reshape(B, D)
    fs, _ = batch_eval(Xf)
    return MultistartOutcome(
        x=Xf,
        f=np.asarray(fs),
        n_iters=np.full(B, st.n_iters),
        n_evals=np.full(B, st.n_evals),
        n_rounds=n_rounds,
        batch_sizes=[B] * n_rounds,
        wall_time=time.perf_counter() - t0,
    )
