"""Per-kernel Pallas tests: interpret=True vs ref.py oracle over
shape/dtype sweeps (the contract required for every kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash.kernel import flash_attention
from repro.kernels.flash.ref import attention_ref
from repro.kernels.kvp.kernel import kvp
from repro.kernels.kvp.ref import kvp_ref
from repro.kernels.matern.kernel import matern52_gram
from repro.kernels.matern.ref import matern52_gram_ref

SHAPES_MATERN = [(7, 13, 5), (128, 128, 8), (130, 250, 40), (1, 257, 3)]
DTYPES = [jnp.float32]


@pytest.mark.parametrize("n1,n2,d", SHAPES_MATERN)
@pytest.mark.parametrize("dtype", DTYPES)
def test_matern_gram(n1, n2, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n1 * 7 + d), 3)
    x1 = jax.random.normal(k1, (n1, d), dtype)
    x2 = jax.random.normal(k2, (n2, d), dtype)
    ils = jnp.exp(jax.random.normal(k3, (d,), dtype) * 0.3)
    amp = jnp.asarray(1.7, dtype)
    out = matern52_gram(x1, x2, ils, amp, interpret=True)
    ref = matern52_gram_ref(x1, x2, ils, amp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("q,n,d", [(10, 50, 5), (128, 256, 16),
                                   (77, 500, 40), (1, 130, 8)])
def test_kvp(q, n, d):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(q + n), 4)
    xq = jax.random.normal(k1, (q, d), jnp.float32)
    xt = jax.random.normal(k2, (n, d), jnp.float32)
    al = jax.random.normal(k3, (n,), jnp.float32)
    ils = jnp.exp(jax.random.normal(k4, (d,), jnp.float32) * 0.3)
    amp = jnp.asarray(2.1, jnp.float32)
    out = kvp(xq, xt, al, ils, amp, interpret=True)
    ref = kvp_ref(xq, xt, al, ils, amp)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=1e-5)


FLASH_CASES = [
    (256, 256, 64, True, None, jnp.float32),
    (256, 256, 64, False, None, jnp.float32),
    (128, 384, 64, True, None, jnp.float32),    # suffix-aligned (cache)
    (300, 300, 32, True, 128, jnp.float32),     # local window, ragged
    (1, 513, 64, True, None, jnp.float32),      # single-query decode
    (128, 128, 64, True, None, jnp.bfloat16),   # dtype sweep
]


@pytest.mark.parametrize("sq,sk,h,causal,window,dtype", FLASH_CASES)
def test_flash_attention(sq, sk, h, causal, window, dtype):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(sq + sk), 3)
    q = jax.random.normal(kq, (sq, h), dtype)
    k = jax.random.normal(kk, (sk, h), dtype)
    v = jax.random.normal(kv, (sk, h), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal,
                        window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=tol, rtol=tol)


def test_flash_blocks_shape_sweep():
    """Block-size robustness: output must not depend on tiling."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (192, 32), jnp.float32)
    k = jax.random.normal(kk, (192, 32), jnp.float32)
    v = jax.random.normal(kv, (192, 32), jnp.float32)
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5)
