"""Reproduce the paper's core phenomena in one run:

1. C3 — D-BE per-restart trajectories are IDENTICAL to SEQ. OPT.
2. C2 — C-BE's off-diagonal artifacts inflate L-BFGS-B iterations.
3. wall-clock — D-BE < C-BE < SEQ. OPT. on batched-evaluation objectives.

    PYTHONPATH=src python examples/paper_repro.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.core.mso import MsoOptions, maximize_acqf   # noqa: E402


def neg_rosen(state, X):
    del state
    return -jax.vmap(lambda x: jnp.sum(
        100.0 * (x[1:] - x[:-1] ** 2) ** 2
        + (1.0 - x[:-1]) ** 2))(X)


def main():
    B, D = 10, 5
    x0 = np.random.default_rng(0).uniform(0, 3, (B, D))
    opts = MsoOptions(m=10, maxiter=200, pgtol=1e-8)

    results = {}
    for s in ("seq", "dbe", "cbe", "dbe_vec"):
        r = maximize_acqf(neg_rosen, x0, 0.0, 3.0, acq_state=None,
                          strategy=s, options=opts)
        results[s] = r
        print(f"{s:8s} best={r.best_acq:+.3e} "
              f"iters(med)={np.median(r.n_iters):6.1f} "
              f"eval_rounds={r.n_rounds:4d} wall={r.wall_time:.2f}s")

    same = np.array_equal(results["seq"].x, results["dbe"].x)
    print(f"\nC3  D-BE trajectories identical to SEQ. OPT.: {same}")
    infl = (np.median(results['cbe'].n_iters)
            / np.median(results['dbe'].n_iters))
    print(f"C2  C-BE iteration inflation vs D-BE: {infl:.1f}x")
    print(f"    D-BE eval rounds vs SEQ: {results['seq'].n_rounds} -> "
          f"{results['dbe'].n_rounds} "
          f"({results['seq'].n_rounds / results['dbe'].n_rounds:.1f}x fewer)")


if __name__ == "__main__":
    main()
