"""Fixture: jit cache keys derived from live scheduler state — every
pattern here must trip ``recompile-hazard``."""
from repro.engine.cache import CountingJit


def _bad_step(engine, X):
    # closure-captured live state: the queue length is baked into the
    # compiled program as a constant
    return X[: len(engine._queue)]


class Scheduler:
    def __init__(self):
        self._studies = {}
        self._ask_jit = CountingJit(_bad_step)

    def ask(self, X):
        # BAD: live-study count as an argument to a jit program — every
        # admit/evict mints a fresh executable
        return self._ask_jit(len(self._studies), X)

    def rebuild_per_call(self, fn, X):
        # BAD (warning): per-call wrapper construction defeats the cache
        prog = CountingJit(fn)
        return prog(X)
