"""LogEI stability tests (Ament et al. 2023 numerics) + properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st
from scipy.stats import norm

from repro.core.acquisition import ei, log_ei, log_h


def h_ref(z):
    """φ(z) + zΦ(z) with scipy (float64 reference)."""
    return norm.pdf(z) + z * norm.cdf(z)


def test_log_h_matches_reference_moderate():
    z = jnp.linspace(-8, 6, 200, dtype=jnp.float64)
    ours = np.asarray(log_h(z))
    ref = np.log(h_ref(np.asarray(z)))
    np.testing.assert_allclose(ours, ref, rtol=1e-6)


def test_log_h_extreme_negative_finite():
    """Direct evaluation underflows long before z=-30; log_h must not."""
    z = jnp.asarray([-10.0, -20.0, -50.0, -100.0, -1000.0], jnp.float64)
    out = np.asarray(log_h(z))
    assert np.all(np.isfinite(out))
    # asymptotic: log h(z) ≈ -z²/2 - log√(2π) - 2 log|z|
    approx = -z**2 / 2 - 0.5 * np.log(2 * np.pi) - 2 * np.log(-z)
    np.testing.assert_allclose(out, np.asarray(approx), rtol=1e-3)


def test_log_h_gradient_finite_everywhere():
    g = jax.vmap(jax.grad(log_h))(jnp.asarray(
        [-100.0, -6.0, -5.9999, -1.0, 0.0, 3.0], jnp.float64))
    assert np.all(np.isfinite(np.asarray(g)))


def test_logei_consistent_with_ei():
    mean = jnp.asarray([0.0, 0.5, -0.5, 2.0], jnp.float64)
    var = jnp.asarray([1.0, 0.25, 4.0, 0.01], jnp.float64)
    best = jnp.asarray(0.3, jnp.float64)
    np.testing.assert_allclose(
        np.asarray(jnp.exp(log_ei(mean, var, best))),
        np.asarray(ei(mean, var, best)), rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(mu=st.floats(-5, 5), best=st.floats(-5, 5),
       var=st.floats(1e-4, 10.0))
def test_property_logei_monotone_in_mean(mu, best, var):
    """LogEI increases with the posterior mean (all else equal)."""
    lo = log_ei(jnp.asarray(mu, jnp.float64), jnp.asarray(var, jnp.float64),
                jnp.asarray(best, jnp.float64))
    hi = log_ei(jnp.asarray(mu + 0.1, jnp.float64),
                jnp.asarray(var, jnp.float64),
                jnp.asarray(best, jnp.float64))
    assert float(hi) >= float(lo)


@settings(max_examples=30, deadline=None)
@given(mu=st.floats(-50, 50), best=st.floats(-50, 50),
       var=st.floats(1e-6, 100.0))
def test_property_logei_finite(mu, best, var):
    v = log_ei(jnp.asarray(mu, jnp.float64), jnp.asarray(var, jnp.float64),
               jnp.asarray(best, jnp.float64))
    assert np.isfinite(float(v))
