"""The write-ahead study journal — durable ask/tell history for the fleet.

The fleet serves untrusted, long-lived traffic (ROADMAP item 3): clients
die mid-trial, processes get preempted mid-suggest, and a crash must not
lose the studies it was serving.  This module is the durability layer
under :class:`repro.bo.sampler.FleetSampler`:

* **append-only** — one record per line, written before the state change
  it describes takes effect (WAL discipline: an ask is journaled before
  the suggestion is handed out, a tell before it enters GP data);
* **fsync'd** — every append flushes and fsyncs by default, so a crash
  loses at most the record being written, never an acknowledged one;
* **checksummed** — each line carries a CRC-32 of its JSON payload plus a
  monotonically increasing sequence number; on open, the tail is scanned
  and the first corrupt, partial, or out-of-sequence record (the
  signature of a crash mid-append) truncates the file there — the same
  "atomic or absent" semantics :mod:`repro.ckpt.manager` gives whole
  checkpoints via tmp-file + ``os.replace``.

Recovery (:meth:`FleetSampler.recover`) replays the journal through the
normal sampler/scheduler paths: completed tells re-enter via the existing
out-of-order observation sync, studies re-admit through the slot
scheduler, and device factors are rebuilt by the first post-recovery full
refit — exactly like a post-migration suggest, so recovery adds NO new
compiled programs.  :class:`repro.ckpt.manager.CheckpointManager`
snapshots (``save_flat``) bound how much journal has to be replayed.

Record payloads are plain dicts with an ``"op"`` key; the journal is
schema-agnostic (the sampler owns the vocabulary).  A fault injector (see
``tests/faults.py``) may hook ``append`` to simulate a crash at an exact
journal offset — it writes a *partial* record and raises
:class:`InjectedCrash`, which is precisely the on-disk state a real kill
mid-append leaves behind.
"""
from __future__ import annotations

import json
import os
import warnings
import zlib
from typing import Any, Dict, List, Optional

from repro.obs.trace import get as _obs_get

JOURNAL_NAME = "journal.log"


class InjectedCrash(RuntimeError):
    """Raised by a fault injector to simulate a process kill at an exact
    journal offset (after a deliberately partial record write)."""


class StudyJournal:
    """Append-only, fsync'd, checksummed per-fleet study journal."""

    def __init__(self, directory: str, *, sync: bool = True,
                 fault_injector: Optional[Any] = None):
        self.dir = directory
        self.sync = sync
        self.fault_injector = fault_injector
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, JOURNAL_NAME)
        # resume-safe: scan any existing log (truncating a torn tail) so
        # appends continue the sequence instead of corrupting it
        records, truncated = self._scan_and_truncate(self.path)
        self.seq = records[-1]["seq"] + 1 if records else 0
        self.truncated_bytes = truncated
        self._f = open(self.path, "ab")

    # ------------------------------------------------------------- append
    def append(self, record: Dict[str, Any]) -> int:
        """Durably append one record; returns its sequence number.

        The record is on disk (flushed + fsync'd) before this returns —
        callers rely on WAL ordering: journal first, then mutate state.
        """
        if self._f is None:
            raise ValueError("journal is closed")
        seq = self.seq
        payload = json.dumps({"seq": seq, **record},
                             separators=(",", ":"))
        data = self._encode(payload)
        fi = self.fault_injector
        if fi is not None and fi.should_kill(seq):
            # a real kill mid-append leaves a torn record: write a
            # prefix, make it durable, and die
            self._f.write(data[: max(1, len(data) // 2)])
            self._f.flush()
            os.fsync(self._f.fileno())
            raise InjectedCrash(f"injected crash at journal seq {seq}")
        tr = _obs_get()
        t0 = tr.now_us() if tr is not None else 0.0
        self._f.write(data)
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())
        if tr is not None:
            # the durability cost of WAL discipline, per record: write +
            # flush (+ fsync when sync=True) as one timeline span
            tr.record_span("journal.append", t0, tr.now_us() - t0,
                           op=record.get("op", "?"), seq=seq,
                           n_bytes=len(data), fsync=self.sync)
        self.seq = seq + 1
        return seq

    @staticmethod
    def _encode(payload: str) -> bytes:
        crc = zlib.crc32(payload.encode())
        return f"{crc:08x} {payload}\n".encode()

    def close(self) -> None:
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    # ------------------------------------------------------------- replay
    def replay(self) -> List[Dict[str, Any]]:
        """All intact records, in order (the truncation already happened
        at open time; this is a pure read)."""
        records, _ = self._scan_and_truncate(self.path, truncate=False)
        return records

    @staticmethod
    def _scan_and_truncate(path: str, truncate: bool = True
                           ) -> "tuple[List[Dict[str, Any]], int]":
        """Read records up to the first corrupt/partial/out-of-sequence
        line; truncate the file there (a crash mid-append must look like
        the append never happened).  Returns (records, bytes_dropped)."""
        if not os.path.exists(path):
            return [], 0
        records: List[Dict[str, Any]] = []
        good_end = 0
        with open(path, "rb") as f:
            data = f.read()
        pos = 0
        while pos < len(data):
            nl = data.find(b"\n", pos)
            if nl < 0:
                break                            # partial tail record
            line = data[pos:nl]
            rec = StudyJournal._decode(line, expect_seq=len(records))
            if rec is None:
                break                            # corrupt from here on
            records.append(rec)
            good_end = nl + 1
            pos = nl + 1
        dropped = len(data) - good_end
        if dropped and truncate:
            warnings.warn(
                f"journal {path}: dropping {dropped} bytes of "
                f"corrupt/partial tail after record {len(records) - 1}")
            with open(path, "r+b") as f:
                f.truncate(good_end)
        return records, dropped

    @staticmethod
    def _decode(line: bytes, expect_seq: int) -> Optional[Dict[str, Any]]:
        try:
            crc_hex, payload = line.split(b" ", 1)
            if int(crc_hex, 16) != zlib.crc32(payload):
                return None
            rec = json.loads(payload)
        except (ValueError, json.JSONDecodeError):
            return None
        if rec.get("seq") != expect_seq:
            return None                # a rewind/gap is corruption too
        return rec
