"""BO-as-a-service tests: DRR weighted fairness / starvation freedom,
deadline budgets under a virtual clock, bounded backoff retries (service
and engine level), the overload ladder, drain semantics, journal replay
of in-flight service requests, and the out-of-order tell property.

Everything timing-related runs on :class:`faults.VirtualClock` — no real
sleeps, no wall-clock margins."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from faults import FaultInjector, VirtualClock
from repro.bo.journal import InjectedCrash, StudyJournal
from repro.bo.sampler import FleetSampler
from repro.bo.space import BoxSpace
from repro.core.mso import MsoOptions
from repro.engine import FleetFullError
from repro.serve.bo_service import (BOService, DeadlineExceeded,
                                    OverloadConfig, RequestFailed,
                                    ServiceDraining, TenantConfig,
                                    TenantShedError)
import os

_MSO = MsoOptions(maxiter=40, pgtol=1e-2)


def _sphere(x):
    return float(np.sum((x - 0.4) ** 2))


def _fleet_kw(**over):
    kw = dict(n_startup_trials=4, n_restarts=4, pad_multiple=8, slots=4,
              posterior_backend="xla", refit_interval=1, warm_start=False,
              mso_options=MsoOptions(**vars(_MSO)))
    kw.update(over)
    return kw


def _journal_records(d):
    path = os.path.join(d, "journal.log")
    return StudyJournal._scan_and_truncate(path, truncate=False)[0]


def _mk_service(n_studies, tenants, *, journal_dir=None, fi=None,
                clock=None, fleet_over=None, **svc_kw):
    clock = clock if clock is not None else VirtualClock()
    fs = FleetSampler([BoxSpace.cube(2, 0.0, 1.0)] * n_studies, seed=0,
                      journal_dir=journal_dir, fault_injector=fi,
                      sleep_fn=clock.sleep, **_fleet_kw(
                          **(fleet_over or {})))
    return BOService(fs, tenants, clock=clock, **svc_kw), clock


def _serve(svc, reqs, max_steps=50):
    for _ in range(max_steps):
        if all(r.done for r in reqs):
            return
        svc.service_step()
    raise AssertionError(
        f"requests not served: {[(r.rid, r.state) for r in reqs]}")


# ============================================= DRR fairness / starvation
def test_drr_weighted_fairness_and_no_starvation():
    """A heavy tenant flooding its queues must not delay a light
    tenant's requests: DRR gives the light tenant its weighted share
    every round, so its per-request latency is bounded (one round)
    regardless of the flood."""
    svc, _ = _mk_service(4, [
        TenantConfig("heavy", weight=2.0, studies=(0, 1)),
        TenantConfig("light", weight=1.0, studies=(2,)),
        TenantConfig("slow", weight=0.5, studies=(3,)),
    ])
    flood = [svc.submit_ask("heavy", s) for _ in range(6) for s in (0, 1)]
    slow_reqs = []
    for rnd in range(8):
        light = svc.submit_ask("light", 2)
        slow_reqs.append(svc.submit_ask("slow", 3))
        svc.service_step()
        # starvation freedom: light is served the round it was submitted
        assert light.done and light.result is not None, \
            f"round {rnd}: light starved ({light.state})"
    assert all(r.done for r in flood)
    snap = svc.stats_snapshot()["svc_tenants"]
    assert snap["heavy"]["served"] == 12
    assert snap["light"]["served"] == 8
    # weight 0.5 accumulates a unit deficit every other round
    assert 3 <= snap["slow"]["served"] <= 4
    assert svc.n_shed == 0 and svc.n_rejected == 0


def test_drr_one_inflight_per_study_per_round():
    """A study's suggest is one slot reservation: two queued asks for
    the same study serve on consecutive rounds, not the same one."""
    svc, _ = _mk_service(1, [TenantConfig("a", studies=(0,))])
    r1, r2 = svc.submit_ask("a", 0), svc.submit_ask("a", 0)
    assert svc.service_step() == 1
    assert r1.done and not r2.done
    assert svc.service_step() == 1
    assert r2.done
    assert r1.result.trial_id != r2.result.trial_id


# ============================================================ deadlines
def test_deadline_shed_while_queued(tmp_path):
    d = str(tmp_path)
    svc, clock = _mk_service(2, [TenantConfig("a", studies=(0, 1))],
                             journal_dir=d)
    req = svc.submit_ask("a", 0, deadline=0.5)
    ok = svc.submit_ask("a", 1, deadline=10.0)
    clock.advance(1.0)                     # past req's budget, not ok's
    svc.service_step()
    assert req.state == "shed" and isinstance(req.error, DeadlineExceeded)
    assert ok.done and ok.result is not None
    snap = svc.stats_snapshot()
    assert snap["svc_deadline_miss"] == 1 and snap["svc_shed"] == 1
    recs = [r for r in _journal_records(d) if r["op"] == "svc_shed"]
    assert len(recs) == 1 and recs[0]["req"] == req.rid
    assert "deadline" in recs[0]["reason"]
    assert recs[0]["kind"] == "deadline"   # replay keeps the error class
    # the freed study keeps serving; a later ask just works
    again = svc.submit_ask("a", 0)
    svc.service_step()
    assert again.done and again.result is not None


def test_deadline_miss_in_flight_via_injected_latency(tmp_path):
    """A suggestion that comes back after its deadline (injected
    full-refit latency on the virtual clock) is cancel-and-shed: the
    request fails, the trial is never told, the slot reservation is
    withdrawn, and the shed is journaled."""
    d = str(tmp_path)
    tenants = [TenantConfig("a", studies=(0,)), TenantConfig("b",
                                                             studies=(1,))]
    fi = FaultInjector()
    svc, clock = _mk_service(2, tenants, journal_dir=d, fi=fi)
    for _ in range(5):                     # through startup into GP asks
        reqs = [svc.submit_ask("a", 0), svc.submit_ask("b", 1)]
        _serve(svc, reqs)
        for r in reqs:
            svc.submit_tell(r.tenant, r.study, r.result.trial_id,
                            _sphere(r.result.x))
    n_before = len(svc.fs.samplers[0].trials)
    fi.full_latency[0] = [10.0, 1]         # next full refit: +10 virtual s
    late = svc.submit_ask("a", 0, deadline=5.0)
    intime = svc.submit_ask("b", 1, deadline=100.0)
    _serve(svc, [late, intime])
    assert late.state == "shed" and isinstance(late.error,
                                               DeadlineExceeded)
    assert "in flight" in str(late.error)
    assert intime.done and intime.result is not None
    assert fi.n_full_delays == 1 and clock.slept_s >= 10.0
    # the computed trial exists but stays pending (recovery re-evaluates)
    assert svc.fs.samplers[0].trials[n_before].state == "pending"
    recs = [r for r in _journal_records(d) if r["op"] == "svc_shed"]
    assert len(recs) == 1 and recs[0]["req"] == late.rid


# ====================================================== backoff retries
def test_transient_dispatch_failure_retries_with_bounded_backoff(
        tmp_path):
    d = str(tmp_path)
    svc, clock = _mk_service(
        1, [TenantConfig("a", studies=(0,))], journal_dir=d,
        fi=FaultInjector(ask_fail={0: 3}), max_retries=5,
        backoff_base=0.1, backoff_cap=0.25, backoff_jitter=0.25)
    req = svc.submit_ask("a", 0)
    for _ in range(20):
        if req.done:
            break
        svc.service_step()
        clock.advance(0.5)                 # release the backoff
    assert req.done and req.result is not None
    assert req.attempts == 4               # 3 vetoes + 1 success
    recs = [r for r in _journal_records(d) if r["op"] == "svc_retry"]
    assert [r["attempt"] for r in recs] == [1, 2, 3]
    for i, r in enumerate(recs):
        base = min(0.1 * 2.0 ** i, 0.25)   # bounded: cap then jitter
        assert base <= r["delay_s"] <= base * 1.25
    assert recs[0]["delay_s"] < recs[1]["delay_s"]
    snap = svc.stats_snapshot()
    assert snap["svc_retries"] == 3 and snap["svc_shed"] == 0


def test_retry_exhaustion_fails_request_and_isolates_tenant(tmp_path):
    d = str(tmp_path)
    svc, clock = _mk_service(
        2, [TenantConfig("a", studies=(0,)), TenantConfig("b",
                                                          studies=(1,))],
        journal_dir=d,
        fi=FaultInjector(ask_fail={0: 99}), max_retries=2,
        backoff_base=0.01, backoff_cap=0.02)
    bad = svc.submit_ask("a", 0)
    good = svc.submit_ask("b", 1)
    for _ in range(20):
        if bad.done and good.done:
            break
        svc.service_step()
        clock.advance(0.1)
    assert good.done and good.result is not None     # isolation
    assert bad.state == "failed" and isinstance(bad.error, RequestFailed)
    assert bad.attempts == 3               # initial + max_retries
    recs = [r for r in _journal_records(d) if r["op"] == "svc_shed"]
    assert len(recs) == 1 and recs[0]["kind"] == "failed"
    assert "retries exhausted" in recs[0]["reason"]


def test_backoff_delays_deterministic_across_runs(tmp_path):
    """Same seeds, same faults → bit-identical jittered delay sequence
    (the backoff rng is fixed-seed; no wall clock leaks in)."""
    def run(sub):
        d = str(tmp_path / sub)
        svc, clock = _mk_service(
            1, [TenantConfig("a", studies=(0,))], journal_dir=d,
            fi=FaultInjector(ask_fail={0: 3}), max_retries=5)
        req = svc.submit_ask("a", 0)
        for _ in range(20):
            if req.done:
                break
            svc.service_step()
            clock.advance(1.0)
        return [r["delay_s"] for r in _journal_records(d)
                if r["op"] == "svc_retry"]
    a, b = run("a"), run("b")
    assert len(a) == 3 and a == b


def test_engine_quarantine_retry_backoff_counters(tmp_path):
    """Satellite: the fleet's quarantine retry loop honors bounded
    exponential backoff (journaled, charged to the sleep hook) and
    surfaces retry/backoff counters in stats_snapshot()."""
    d = str(tmp_path)
    clock = VirtualClock()
    inj = FaultInjector(full_fail={1: 1})
    fs = FleetSampler([BoxSpace.cube(2, 0.0, 1.0)] * 2, seed=2,
                      journal_dir=d, fault_injector=inj,
                      sleep_fn=clock.sleep,
                      **_fleet_kw(retry_backoff_base=0.05,
                                  retry_backoff_cap=0.4,
                                  retry_backoff_jitter=0.25))
    for _ in range(6):
        for i, t in enumerate(fs.ask_all()):
            fs.tell(i, t.trial_id, _sphere(t.x))
    assert inj.n_full_vetoed == 1
    snap = fs.stats_snapshot()
    assert snap["n_retries"] >= 1 and snap["n_retry_backoffs"] >= 1
    assert snap["backoff_total_s"] > 0.0
    recs = [r for r in _journal_records(d) if r["op"] == "backoff"]
    assert len(recs) == snap["n_retry_backoffs"]
    for r in recs:
        assert 0.05 <= r["delay_s"] <= 0.4 * 1.25 and 1 in r["sids"]
    # the delay was charged to the (virtual) sleep hook, not wall time
    assert clock.slept_s == pytest.approx(snap["backoff_total_s"])
    # compile economy: retries + backoff reuse the same programs
    assert snap["n_fleet_compiles"] <= 3


def test_cancel_ask_is_deterministic_to_undo():
    """cancel_request withdraws a pending/uncollected suggest; because
    keys derive from the trial count, re-asking recomputes the identical
    point — a deadline shed never perturbs the trajectory."""
    def mk():
        return FleetSampler([BoxSpace.cube(2, 0.0, 1.0)] * 2, seed=4,
                            **_fleet_kw())
    a, b = mk(), mk()
    for fs in (a, b):
        for _ in range(5):
            for i, t in enumerate(fs.ask_all()):
                fs.tell(i, t.trial_id, _sphere(t.x))
    # a: prefetch + step + cancel (sheds the computed result), then ask
    assert a.samplers[0].prefetch_suggest()
    a.fleet.step()
    assert a.cancel_ask(0) is True
    assert a.cancel_ask(0) is False        # nothing left to withdraw
    ta = a.ask_batch([0])[0]
    tb = b.ask_batch([0])[0]
    np.testing.assert_array_equal(ta.x, tb.x)


# ======================================================= overload ladder
def test_overload_reject_rung_and_deescalation(tmp_path):
    d = str(tmp_path)
    svc, _ = _mk_service(
        2, [TenantConfig("a", studies=(0,)), TenantConfig("b",
                                                          studies=(1,))],
        journal_dir=d,
        overload=OverloadConfig(reject_depth=3, degrade_depth=50,
                                shed_depth=60))
    backlog = [svc.submit_ask("a", 0) for _ in range(3)]
    svc.service_step()                     # depth 3 >= 3: rung -> reject
    assert svc.stats_snapshot()["svc_rung"] == "reject"
    with pytest.raises(FleetFullError, match="rung reject"):
        svc.submit_ask("b", 1)
    assert svc.stats_snapshot()["svc_tenants"]["b"]["rejected"] == 1
    _serve(svc, backlog)                   # queue drains...
    svc.service_step()
    assert svc.stats_snapshot()["svc_rung"] == "admit"     # ...de-escalates
    ok = svc.submit_ask("b", 1)            # admissions resume
    svc.service_step()
    assert ok.done and ok.result is not None
    rungs = [(r["from"], r["rung"]) for r in _journal_records(d)
             if r["op"] == "svc_overload"]
    assert rungs == [("admit", "reject"), ("reject", "admit")]
    recs = [r for r in _journal_records(d) if r["op"] == "svc_reject"]
    assert len(recs) == 1 and recs[0]["tenant"] == "b"


def test_overload_degrade_and_shed_lowest_weight_tenant(tmp_path):
    d = str(tmp_path)
    svc, _ = _mk_service(
        3, [TenantConfig("gold", weight=4.0, studies=(0,)),
            TenantConfig("silver", weight=2.0, studies=(1,)),
            TenantConfig("bronze", weight=1.0, studies=(2,))],
        journal_dir=d,
        overload=OverloadConfig(reject_depth=2, degrade_depth=4,
                                shed_depth=6))
    backlog = [svc.submit_ask("gold", 0) for _ in range(3)]
    backlog += [svc.submit_ask("bronze", 2) for _ in range(3)]
    victim = svc.submit_ask("bronze", 2)   # depth 7 >= 6 at next step
    svc.service_step()
    snap = svc.stats_snapshot()
    assert snap["svc_rung"] == "shed_tenant"
    t = snap["svc_tenants"]
    # rung 2 degraded silver... no: both actions pick the lowest weight
    # still standing — bronze degrades (solo path), then is shed
    assert t["bronze"]["is_shed"] and t["bronze"]["degraded"]
    assert not t["gold"]["is_shed"] and not t["gold"]["degraded"]
    assert not t["silver"]["is_shed"]
    assert svc.fs.samplers[2]._fleet is None      # left the fleet plane
    assert svc.fs.samplers[0]._fleet is not None
    assert victim.state == "shed" and isinstance(victim.error,
                                                 TenantShedError)
    with pytest.raises(TenantShedError):
        svc.submit_ask("bronze", 2)
    with pytest.raises(TenantShedError):
        svc.submit_tell("bronze", 2, 0, 1.0)
    recs = _journal_records(d)
    deg = [r for r in recs if r["op"] == "svc_degrade"]
    shd = [r for r in recs if r["op"] == "svc_shed_tenant"]
    assert len(deg) == 1 and deg[0]["tenant"] == "bronze"
    assert len(shd) == 1 and shd[0]["tenant"] == "bronze"
    assert victim.rid in shd[0]["dropped"]
    # the WAL shows the rung transition before its effects
    ops = [r["op"] for r in recs]
    assert ops.index("svc_overload") < ops.index("svc_degrade") \
        < ops.index("svc_shed_tenant")
    # gold keeps being served after the shed; once its backlog drains
    # the ladder de-escalates and admissions resume
    _serve(svc, backlog)
    svc.service_step()
    assert svc.stats_snapshot()["svc_rung"] == "admit"
    ok = svc.submit_ask("gold", 0)
    _serve(svc, [ok])
    assert ok.result is not None


def test_tenant_shed_resolves_backoff_delayed_requests(tmp_path):
    """Shedding a tenant resolves its backoff-delayed requests exactly
    like its queued ones (TenantShedError, counted, in the journal drop
    list) — no client is left polling a request that can never finish."""
    d = str(tmp_path)
    svc, _ = _mk_service(
        2, [TenantConfig("big", weight=2.0, studies=(0,)),
            TenantConfig("small", weight=1.0, studies=(1,))],
        journal_dir=d, fi=FaultInjector(ask_fail={1: 99}),
        overload=OverloadConfig(reject_depth=2, degrade_depth=4,
                                shed_depth=6))
    stuck = svc.submit_ask("small", 1)
    svc.service_step()                     # dispatch veto -> backoff
    assert stuck.state == "delayed"
    backlog = [svc.submit_ask("big", 0) for _ in range(6)]
    svc.service_step()                     # depth 7 >= 6: shed small
    assert svc.stats_snapshot()["svc_rung"] == "shed_tenant"
    assert stuck.done and stuck.state == "shed"
    assert isinstance(stuck.error, TenantShedError)
    snap = svc.stats_snapshot()["svc_tenants"]["small"]
    assert snap["shed"] == 1 and snap["is_shed"]
    shd = [r for r in _journal_records(d)
           if r["op"] == "svc_shed_tenant"]
    assert len(shd) == 1 and stuck.rid in shd[0]["dropped"]
    _serve(svc, backlog)                   # the survivor keeps serving


def test_p99_rung_deescalates_after_queue_drains(tmp_path):
    """SLO-driven reject must not latch: p99 only refreshes on
    completions, so once the backlog drains the p99 rungs suspend and
    admissions resume (regression: a stale over-SLO window used to
    lock the service in reject forever)."""
    d = str(tmp_path)
    svc, clock = _mk_service(
        1, [TenantConfig("a", studies=(0,))], journal_dir=d,
        overload=OverloadConfig(reject_depth=1000, p99_slo=0.6,
                                min_samples=3, window=8))
    for _ in range(3):                     # over-SLO window: ~1s each
        req = svc.submit_ask("a", 0)
        clock.advance(1.0)
        svc.service_step()
        assert req.done and req.result is not None
    assert svc.p99() >= 1.0
    queued = svc.submit_ask("a", 0)        # backlog: p99 rung engages
    svc.service_step()
    assert queued.done                     # rung 1 serves the backlog
    assert svc.stats_snapshot()["svc_rung"] == "reject"
    with pytest.raises(FleetFullError, match="p99"):
        svc.submit_ask("a", 0)
    svc.service_step()                     # empty queue: p99 suspends
    assert svc.stats_snapshot()["svc_rung"] == "admit"
    ok = svc.submit_ask("a", 0)            # admissions resume
    svc.service_step()
    assert ok.done and ok.result is not None
    rungs = [(r["from"], r["rung"]) for r in _journal_records(d)
             if r["op"] == "svc_overload"]
    # the stale window may re-engage while ok is queued (it still gets
    # served); what must hold is the engage/de-escalate pair, not a
    # permanent latch
    assert rungs[:2] == [("admit", "reject"), ("reject", "admit")]


def test_tenant_queue_cap_isolates_backlog_spam():
    svc, _ = _mk_service(
        2, [TenantConfig("spam", studies=(0,)), TenantConfig("calm",
                                                             studies=(1,))],
        overload=OverloadConfig(reject_depth=100, tenant_queue_cap=2))
    for _ in range(2):
        svc.submit_ask("spam", 0)
    with pytest.raises(FleetFullError, match="backlog"):
        svc.submit_ask("spam", 0)
    ok = svc.submit_ask("calm", 1)         # unaffected by spam's cap
    svc.service_step()
    assert ok.done and ok.result is not None


def test_nan_tell_spam_costs_only_the_spammer(tmp_path):
    """Poison tells are refused synchronously before the WAL: the
    spammer sees ValueError, the journal never acknowledges, and other
    tenants' service is untouched."""
    d = str(tmp_path)
    svc, _ = _mk_service(2, [TenantConfig("spam", studies=(0,)),
                             TenantConfig("calm", studies=(1,))],
                         journal_dir=d)
    t = svc.submit_ask("spam", 0)
    svc.service_step()
    n_recs = len(_journal_records(d))
    for _ in range(5):
        with pytest.raises(ValueError, match="failed=True"):
            svc.submit_tell("spam", 0, t.result.trial_id, float("nan"))
    assert len(_journal_records(d)) == n_recs      # nothing acknowledged
    assert svc.stats_snapshot()["svc_tenants"]["spam"]["bad_tells"] == 5
    ok = svc.submit_ask("calm", 1)
    svc.service_step()
    assert ok.done and ok.result is not None


# ========================================================= drain/recover
def test_drain_journals_pending_queue_and_recover_restores_it(tmp_path):
    d = str(tmp_path)
    svc, _ = _mk_service(2, [TenantConfig("a", studies=(0,)),
                             TenantConfig("b", studies=(1,))],
                         journal_dir=d, max_batch=1)
    served = svc.submit_ask("a", 0)
    held = [svc.submit_ask("b", 1), svc.submit_ask("a", 0)]
    svc.service_step()                     # max_batch=1: serves only one
    assert served.done
    svc.drain()
    for r in held:
        assert r.state == "shed" and isinstance(r.error, ServiceDraining)
    recs = _journal_records(d)
    dr = [r for r in recs if r["op"] == "svc_drain"]
    assert len(dr) == 1
    assert dr[0]["queued"] == sorted(r.rid for r in held)
    assert recs[-1]["op"] == "drain"       # fleet drained after service
    with pytest.raises(ServiceDraining):
        svc.submit_ask("a", 0)

    svc2, rep = BOService.recover(d, clock=VirtualClock())
    assert rep.truncated_bytes == 0
    restored = svc2.recovered["queued"]
    assert [(r.rid, r.tenant, r.study) for r in restored] == \
           [(r.rid, r.tenant, r.study) for r in held]
    _serve(svc2, restored)
    assert all(r.result is not None for r in restored)


@pytest.mark.parametrize("kill_seq", [18, 40])
def test_service_crash_recovery_bitwise(tmp_path, ref_service_run,
                                        kill_seq):
    """Kill the process (injected) mid-service at a journal offset;
    recover; the restored pending queue re-dispatches and every study's
    suggestion trajectory matches the uninterrupted twin bit-for-bit
    (refit_interval=1)."""
    d = str(tmp_path)
    rounds, ref_x = ref_service_run
    clock = VirtualClock()
    fi = FaultInjector(kill_at_seq=kill_seq)
    svc, _ = _mk_service(2, _SCRIPT_TENANTS, journal_dir=d, fi=fi,
                         clock=clock)
    crashed = False
    try:
        _run_script(svc, rounds)
    except InjectedCrash:
        crashed = True
    assert crashed

    with pytest.warns(UserWarning, match="dropping"):
        svc2, rep = BOService.recover(d, clock=VirtualClock())
    assert rep.truncated_bytes > 0
    # resync: re-tell every asked-but-never-told trial (same objective,
    # same x, same y), then drive the restored queue to completion
    for i, tid in rep.pending:
        owner = svc2._study_owner[i]
        svc2.submit_tell(owner, i, tid,
                         _sphere(svc2.fs.samplers[i].trials[tid].x))
    queued = svc2.recovered["queued"]
    if queued:
        _serve(svc2, queued)
        for r in queued:
            svc2.submit_tell(r.tenant, r.study, r.result.trial_id,
                             _sphere(r.result.x))
    # top up each study independently to the scripted round count
    while True:
        todo = [i for i in range(2)
                if len(svc2.fs.samplers[i].trials) < rounds]
        if not todo:
            break
        reqs = [svc2.submit_ask(svc2._study_owner[i], i) for i in todo]
        _serve(svc2, reqs)
        for r in reqs:
            svc2.submit_tell(r.tenant, r.study, r.result.trial_id,
                             _sphere(r.result.x))
    for i in range(2):
        got = svc2.fs.samplers[i].trials
        assert len(got) >= rounds
        for k in range(rounds):
            np.testing.assert_array_equal(
                ref_x[i][k], got[k].x, err_msg=f"study {i} trial {k}")


_SCRIPT_TENANTS = [TenantConfig("a", weight=2.0, studies=(0,)),
                   TenantConfig("b", weight=1.0, studies=(1,))]


def _run_script(svc, rounds):
    """The canonical scripted workload both the victim and the twin run:
    one ask per tenant per round, served then told."""
    for r in range(rounds):
        if r == 3 and svc.fs.ckpt is not None:
            svc.fs.checkpoint()            # replay starts mid-journal
        reqs = [svc.submit_ask("a", 0), svc.submit_ask("b", 1)]
        _serve(svc, reqs)
        for req in reqs:
            svc.submit_tell(req.tenant, req.study, req.result.trial_id,
                            _sphere(req.result.x))


@pytest.fixture(scope="module")
def ref_service_run():
    rounds = 6
    svc, _ = _mk_service(2, _SCRIPT_TENANTS)
    _run_script(svc, rounds)
    return rounds, [[np.array(t.x) for t in s.trials]
                    for s in svc.fs.samplers]


# ========================================================= async facade
def test_async_ask_resolves_via_event():
    """Clients of the async facade park on an Event until the server
    task resolves their request — results arrive without a sleep(0)
    busy-poll, and tells close the loop."""
    import asyncio
    svc, _ = _mk_service(1, [TenantConfig("a", studies=(0,))])

    async def main():
        server = asyncio.create_task(svc.run())
        t = await asyncio.wait_for(svc.ask("a", 0), timeout=60)
        await svc.tell("a", 0, t.trial_id, _sphere(t.x))
        svc.stop()
        await server
        return t
    t = asyncio.run(main())
    assert t is not None and svc.n_completed == 1
    assert svc.fs.samplers[0].trials[t.trial_id].state == "complete"


def test_async_ask_woken_on_shed():
    """A request that can never complete (perma-vetoed dispatch, then
    deadline expiry in backoff) must wake its async waiter with the
    shed error instead of hanging it forever."""
    import asyncio
    svc, clock = _mk_service(1, [TenantConfig("a", studies=(0,))],
                             fi=FaultInjector(ask_fail={0: 99}))

    async def main():
        server = asyncio.create_task(svc.run())
        task = asyncio.create_task(svc.ask("a", 0, deadline=0.01))
        # let the server dispatch (veto -> backoff), then push the
        # virtual clock past the deadline so the next round sheds it
        for _ in range(200):
            if task.done():
                break
            clock.advance(0.02)
            await asyncio.sleep(0.002)
        with pytest.raises(DeadlineExceeded):
            await asyncio.wait_for(task, timeout=60)
        svc.stop()
        await server
    asyncio.run(main())
    assert svc.n_deadline_miss == 1


# ================================================= out-of-order tells
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_out_of_order_tells_match_direct_drive(seed):
    """Property: the service layer is pure scheduling — under any tenant
    interleaving of tells (including tells held back across round
    boundaries, landing after the next ask), per-study trajectories are
    bit-identical to driving the FleetSampler directly with the same
    per-study ask/tell schedule."""
    rng = np.random.default_rng(seed)
    rounds, S = 5, 2
    order = [rng.permutation(S) for _ in range(rounds)]
    hold = [int(rng.integers(0, S + 1)) for _ in range(rounds)]  # S=none

    svc, _ = _mk_service(S, [TenantConfig("a", studies=(0,)),
                             TenantConfig("b", studies=(1,))],
                         fleet_over=dict(n_startup_trials=2))
    owner = {0: "a", 1: "b"}
    held = {}                              # study -> (trial_id, y)
    for r in range(rounds):
        reqs = [svc.submit_ask(owner[i], i) for i in range(S)]
        _serve(svc, reqs)
        for i, (tid, y) in held.items():   # late: lands AFTER next ask
            svc.submit_tell(owner[i], i, tid, y)
        held = {}
        for i in order[r]:
            t = reqs[i].result
            if i == hold[r]:
                held[i] = (t.trial_id, _sphere(t.x))
            else:
                svc.submit_tell(owner[i], i, t.trial_id, _sphere(t.x))
    for i, (tid, y) in held.items():
        svc.submit_tell(owner[i], i, tid, y)

    fs = FleetSampler([BoxSpace.cube(2, 0.0, 1.0)] * S, seed=0,
                      **_fleet_kw(n_startup_trials=2))
    held = {}
    for r in range(rounds):
        trials = fs.ask_batch(range(S))
        for i, (tid, y) in held.items():
            fs.tell(i, tid, y)
        held = {}
        for i in order[r]:
            t = trials[i]
            assert not isinstance(t, Exception)
            if i == hold[r]:
                held[i] = (t.trial_id, _sphere(t.x))
            else:
                fs.tell(i, t.trial_id, _sphere(t.x))
    for i, (tid, y) in held.items():
        fs.tell(i, tid, y)

    for i in range(S):
        a, b = svc.fs.samplers[i].trials, fs.samplers[i].trials
        assert len(a) == len(b) == rounds
        for ta, tb in zip(a, b):
            np.testing.assert_array_equal(ta.x, tb.x,
                                          err_msg=f"study {i}")
