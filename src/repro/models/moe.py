"""Mixture-of-Experts block: top-k routing, capacity-based dispatch,
expert parallelism over the mesh "model" axis via shard_map.

TPU adaptation notes (DESIGN.md §6): activations arrive data-sharded and
model-replicated (the dense-TP convention), so *dispatch needs no
all-to-all* — every model shard already holds the tokens and gathers the
ones routed to its own experts through index-gather into an (E_loc, C, D)
capacity buffer (gather, not one-hot einsum: the buffer is the only
HBM-resident intermediate).  The combine is one psum over "model" — the
honest EP collective that shows up in the roofline's collective term.

Capacity semantics are GShard-style: per shard, each expert accepts at most
``C = ceil(N_loc·k/E · capacity_factor)`` tokens; overflow tokens drop (their
gate mass is simply lost, renormalization keeps the rest).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import (Boxed, box, constrain,
                                         get_abstract_mesh)
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init

Array = jax.Array


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    p = {
        "router": box(_dense_init(kr, (d, E), jnp.float32, d),
                      "embed", None),
        "w_up": box(_dense_init(k1, (E, d, ff), dtype, d),
                    "experts", "embed", None),
        "w_gate": box(_dense_init(k2, (E, d, ff), dtype, d),
                      "experts", "embed", None),
        "w_down": box(_dense_init(k3, (E, ff, d), dtype, ff),
                      "experts", None, "embed"),
    }
    return p


def _expert_ffn(w_up, w_gate, w_down, xs):
    """xs: (E_loc, C, D) → (E_loc, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", xs, w_up)
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _local_moe(x_flat: Array, router_w: Array, w_up, w_gate, w_down,
               *, k: int, n_experts_global: int, e_start: int,
               capacity: int) -> Tuple[Array, Array]:
    """Per-shard MoE: dispatch local tokens to this shard's experts.

    x_flat: (N, D) local tokens (model-replicated);
    w_*: (E_loc, ...) this shard's experts covering global expert ids
    [e_start, e_start + E_loc).  Returns (partial y (N, D), aux loss).
    """
    N, D = x_flat.shape
    E_loc = w_up.shape[0]
    E = n_experts_global

    logits = (x_flat.astype(jnp.float32) @ router_w)           # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, k)                # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # aux load-balance loss (computed once per shard, identical everywhere)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), 1), 0)
    aux = E * jnp.sum(me * ce)

    # ---- dispatch to the local expert range --------------------------------
    flat_e = expert_idx.reshape(-1)                            # (N*k,)
    flat_g = gate_vals.reshape(-1)
    local_e = flat_e - e_start
    mine = (local_e >= 0) & (local_e < E_loc)
    local_e = jnp.clip(local_e, 0, E_loc - 1)

    # position of each routed pair within its expert (rank over N*k)
    onehot = (jax.nn.one_hot(local_e, E_loc, dtype=jnp.int32)
              * mine[:, None].astype(jnp.int32))               # (N*k, E_loc)
    pos = jnp.cumsum(onehot, axis=0) - onehot                  # exclusive
    pos = jnp.sum(pos * onehot, axis=1)                        # (N*k,)
    keep = mine & (pos < capacity)
    slot = local_e * capacity + pos                            # (N*k,)
    slot = jnp.where(keep, slot, E_loc * capacity)             # spill row

    token_id = jnp.arange(N * k, dtype=jnp.int32) // k         # (N*k,)

    # gather tokens into the capacity buffer (spill row is dropped)
    src = jnp.zeros((E_loc * capacity + 1,), jnp.int32) \
        .at[slot].set(token_id, mode="drop")
    filled = jnp.zeros((E_loc * capacity + 1,), jnp.bool_) \
        .at[slot].set(keep, mode="drop")
    xs = x_flat[src[:-1]] * filled[:-1, None].astype(x_flat.dtype)
    xs = xs.reshape(E_loc, capacity, D)

    ys = _expert_ffn(w_up, w_gate, w_down, xs)                 # (E_loc, C, D)
    ys = ys.reshape(E_loc * capacity, D)

    # combine: scatter-add expert outputs back to tokens, gate-weighted
    contrib = jnp.where(keep, flat_g, 0.0).astype(ys.dtype)
    y = jnp.zeros((N, D), ys.dtype).at[jnp.where(keep, token_id, N)].add(
        ys[jnp.where(keep, slot, 0)] * contrib[:, None], mode="drop")
    return y, aux


def apply_moe(p: dict, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, D) → (y, aux_loss).  EP over the mesh "model" axis."""
    B, S, D = x.shape
    k = cfg.experts_per_token
    E = cfg.n_experts

    mesh = get_abstract_mesh()
    router_w = p["router"].value
    w_up, w_gate, w_down = (p["w_up"].value, p["w_gate"].value,
                            p["w_down"].value)

    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        x_flat = x.reshape(B * S, D)
        cap = max(int(math.ceil(B * S * k / E * cfg.moe_capacity_factor)), 1)
        y, aux = _local_moe(x_flat, router_w, w_up, w_gate, w_down,
                            k=k, n_experts_global=E, e_start=0,
                            capacity=cap)
        return y.reshape(B, S, D), aux

    axis_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    m_size = axis_sizes["model"]
    if E % m_size != 0:
        raise ValueError(f"n_experts={E} not divisible by model={m_size}")
    E_loc = E // m_size
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names
                       and B % axis_sizes[a] == 0 and axis_sizes[a] > 1)
    b_shards = math.prod(axis_sizes[a] for a in batch_axes) if batch_axes \
        else 1
    n_loc = (B // b_shards) * S
    cap = max(int(math.ceil(n_loc * k / E * cfg.moe_capacity_factor)), 1)

    def shard_fn(xs, rw, wu, wg, wd):
        # xs: (B_loc, S, D); wu/wg/wd: (E_loc, ...)
        m_idx = lax.axis_index("model")
        e_start = m_idx * E_loc
        y, aux = _local_moe(xs.reshape(-1, D), rw, wu, wg, wd,
                            k=k, n_experts_global=E, e_start=e_start,
                            capacity=cap)
        # combine across expert shards (each shard holds partial sums for
        # all of its local tokens) — the EP collective.
        y = lax.psum(y, "model")
        aux = lax.pmean(aux, "model")
        return y.reshape(xs.shape), aux

    bspec = batch_axes if len(batch_axes) > 1 else \
        (batch_axes[0] if batch_axes else None)
    y, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )(x, router_w, w_up, w_gate, w_down)
    return y, aux
