"""Unit + property tests for the batched bound-constrained L-BFGS-B."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from scipy.optimize import minimize

from repro.core.lbfgsb import (CONV_PGTOL, LbfgsbOptions, bfgs_minimize,
                               inv_hessian_dense, lbfgsb_minimize,
                               make_batched_value_and_grad)


def rosen(x):
    return jnp.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2
                   + (1.0 - x[:-1]) ** 2)


def quad(x):
    return jnp.sum((x - 0.3) ** 2 * jnp.arange(1, x.shape[0] + 1))


FB_ROSEN = make_batched_value_and_grad(rosen)
FB_QUAD = make_batched_value_and_grad(quad)


def test_matches_scipy_on_rosenbrock():
    B, D = 6, 5
    x0 = jax.random.uniform(jax.random.PRNGKey(0), (B, D),
                            minval=0.0, maxval=3.0, dtype=jnp.float64)
    opts = LbfgsbOptions(m=10, maxiter=500, pgtol=1e-8, ftol=0.0)
    res = lbfgsb_minimize(FB_ROSEN, x0, 0.0, 3.0, opts)
    for b in range(B):
        r = minimize(lambda z: float(rosen(jnp.asarray(z))),
                     np.asarray(x0[b]),
                     jac=lambda z: np.asarray(jax.grad(rosen)(
                         jnp.asarray(z))),
                     method="L-BFGS-B", bounds=[(0.0, 3.0)] * D,
                     options=dict(maxiter=500, gtol=1e-8, maxcor=10))
        assert float(res.f[b]) < max(r.fun * 10, 1e-12), \
            (b, float(res.f[b]), r.fun)


def test_active_bounds_match_scipy():
    """Constrained minimizer on [1.5, 3]^D pins coordinates at bounds."""
    D = 5
    x0 = jnp.full((1, D), 2.5, jnp.float64)
    opts = LbfgsbOptions(maxiter=500, pgtol=1e-10, ftol=0.0)
    res = lbfgsb_minimize(FB_ROSEN, x0, 1.5, 3.0, opts)
    r = minimize(lambda z: float(rosen(jnp.asarray(z))), np.asarray(x0[0]),
                 jac=lambda z: np.asarray(jax.grad(rosen)(jnp.asarray(z))),
                 method="L-BFGS-B", bounds=[(1.5, 3.0)] * D,
                 options=dict(maxiter=500, gtol=1e-10))
    np.testing.assert_allclose(np.asarray(res.x[0]), r.x, atol=1e-5)


def test_batch_rows_independent():
    """Row b of a batched solve == solving row b alone (decoupling!)."""
    B, D = 5, 4
    x0 = jax.random.uniform(jax.random.PRNGKey(1), (B, D),
                            minval=0.0, maxval=3.0, dtype=jnp.float64)
    opts = LbfgsbOptions(maxiter=200, pgtol=1e-9, ftol=0.0)
    res_all = lbfgsb_minimize(FB_ROSEN, x0, 0.0, 3.0, opts)
    for b in range(B):
        res_one = lbfgsb_minimize(FB_ROSEN, x0[b:b + 1], 0.0, 3.0, opts)
        np.testing.assert_allclose(np.asarray(res_all.x[b]),
                                   np.asarray(res_one.x[0]), atol=1e-10)
        assert int(res_all.k[b]) == int(res_one.k[0])


def test_quadratic_exact_and_fast():
    B, D = 3, 8
    x0 = jnp.zeros((B, D), jnp.float64) + jnp.arange(B)[:, None]
    res = lbfgsb_minimize(FB_QUAD, x0, -10.0, 10.0,
                          LbfgsbOptions(maxiter=100, pgtol=1e-10, ftol=0.0))
    np.testing.assert_allclose(np.asarray(res.x),
                               np.full((B, D), 0.3), atol=1e-6)
    assert np.all(np.asarray(res.k) < 30)


def test_already_converged_at_start():
    x0 = jnp.full((2, 3), 0.3, jnp.float64)
    res = lbfgsb_minimize(FB_QUAD, x0, -1.0, 1.0,
                          LbfgsbOptions(pgtol=1e-6))
    assert np.all(np.asarray(res.status) == CONV_PGTOL)
    assert np.all(np.asarray(res.k) == 0)


def test_maxiter_respected():
    x0 = jnp.full((2, 5), 2.0, jnp.float64)
    res = lbfgsb_minimize(FB_ROSEN, x0, 0.0, 3.0,
                          LbfgsbOptions(maxiter=3, pgtol=1e-14, ftol=0.0))
    assert np.all(np.asarray(res.k) <= 3)


def test_inv_hessian_block_structure():
    """The materialized per-restart inverse Hessian approximates the true
    one — and is per-restart (i.e. block) by construction."""
    B, D = 2, 3
    # both restarts start far from the optimum so the solver builds a
    # meaningful curvature history before converging
    x0 = jnp.asarray([[2.0, 1.0, 0.5], [-2.0, 1.5, -1.0]], jnp.float64)
    res = lbfgsb_minimize(FB_QUAD, x0, -10.0, 10.0,
                          LbfgsbOptions(maxiter=50, pgtol=1e-10, ftol=0.0))
    H = np.asarray(inv_hessian_dense(res.state, 10))
    true_h = np.diag(1.0 / (2.0 * np.arange(1, D + 1)))
    for b in range(B):
        rel = np.linalg.norm(H[b] - true_h) / np.linalg.norm(true_h)
        # inexact (Armijo) line search ⇒ looser curvature capture than
        # exact-line-search BFGS theory; structure is what matters here
        assert rel < 0.35, (b, rel)


def test_bfgs_dense():
    B, D = 4, 4
    x0 = jax.random.uniform(jax.random.PRNGKey(2), (B, D),
                            minval=0.5, maxval=1.5, dtype=jnp.float64)
    res = bfgs_minimize(FB_ROSEN, x0, maxiter=300, gtol=1e-9)
    assert np.all(np.asarray(res.f) < 1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       d=st.integers(2, 6))
def test_property_feasible_and_descending(seed, d):
    """Iterates stay inside the box and f never increases (Armijo)."""
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.uniform(key, (3, d), minval=-2.0, maxval=2.0,
                            dtype=jnp.float64)
    res = lbfgsb_minimize(FB_QUAD, x0, -2.0, 2.0,
                          LbfgsbOptions(maxiter=50, pgtol=1e-8))
    x = np.asarray(res.x)
    assert np.all(x >= -2.0 - 1e-12) and np.all(x <= 2.0 + 1e-12)
    f0 = np.asarray(jax.vmap(quad)(x0))
    assert np.all(np.asarray(res.f) <= f0 + 1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_solution_at_kkt(seed):
    """Projected gradient vanishes at the returned solution."""
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.uniform(key, (2, 4), minval=0.0, maxval=1.0,
                            dtype=jnp.float64)
    res = lbfgsb_minimize(FB_QUAD, x0, 0.0, 0.2,
                          LbfgsbOptions(maxiter=100, pgtol=1e-9, ftol=0.0))
    from repro.core.lbfgsb import projected_grad
    g = jax.vmap(jax.grad(quad))(res.x)
    pg = projected_grad(res.x, g, jnp.asarray(0.0), jnp.asarray(0.2))
    assert float(jnp.max(jnp.abs(pg))) < 1e-6
