"""Property-test compat layer: real hypothesis when installed, otherwise a
minimal deterministic fallback.

The test suite only uses ``@settings(max_examples=..., deadline=None)``,
``@given(name=st.integers(a, b) | st.floats(a, b))``.  The fallback draws
``max_examples`` pseudo-random examples from a fixed-seed generator (plus
the strategy endpoints first, which is where numeric code actually breaks)
and runs the test once per example — weaker than real shrinking/replay, but
it keeps the properties exercised on images where hypothesis cannot be
installed.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import itertools

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, lo, hi, integer):
            self.lo, self.hi, self.integer = lo, hi, integer

        def endpoints(self):
            return (self.lo, self.hi)

        def draw(self, rng):
            if self.integer:
                return int(rng.integers(self.lo, self.hi + 1))
            return float(rng.uniform(self.lo, self.hi))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(min_value, max_value, True)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(float(min_value), float(max_value), False)

    st = _Strategies()

    def settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: preserving fn's signature would make
            # pytest treat the strategy parameters as fixtures
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", 100)
                rng = np.random.default_rng(0)
                names = sorted(strategies)
                corner = list(itertools.islice(
                    itertools.product(
                        *(strategies[k].endpoints() for k in names)),
                    max(n // 4, 1)))
                examples = corner + [
                    tuple(strategies[k].draw(rng) for k in names)
                    for _ in range(max(n - len(corner), 0))]
                for ex in examples[:n]:
                    fn(*args, **dict(zip(names, ex)), **kwargs)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco
