"""GP hyperparameter fitting: MAP over log-parameters with our own batched
L-BFGS-B — the framework eats its own dog food: the GP fit itself is a
multi-start bound-constrained QN problem and runs through `core.lbfgsb`.

Compilation discipline: observations are padded to size *buckets* and the
whole fit (multi-start solver + final Cholesky) is one module-level jitted
function taking data as *arguments* — so a 300-trial BO run compiles the fit
a handful of times (once per bucket), not 300 times.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_solve

from repro.core.lbfgsb import LbfgsbOptions, lbfgsb_minimize
from repro.gp.gpr import GPState, log_marginal_likelihood_masked
from repro.gp.kernels import KernelParams, gram

Array = jax.Array

# Bounds on the log-hyperparameters (unit-cube-normalized x, standardized y).
LOG_LS_BOUNDS = (-4.0, 4.0)
LOG_AMP_BOUNDS = (-6.0, 6.0)
LOG_NOISE_BOUNDS = (-10.0, 2.0)

PAD_BUCKET = 32
_FAR = 1e6          # padded pseudo-points live this far away (kernel → 0)


def _pack(p: KernelParams) -> Array:
    return jnp.concatenate([p.log_lengthscale,
                            p.log_amplitude[None], p.log_noise[None]])


def _unpack(theta: Array, dim: int) -> KernelParams:
    return KernelParams(log_lengthscale=theta[:dim],
                        log_amplitude=theta[dim],
                        log_noise=theta[dim + 1])


def _neg_map_objective(theta: Array, x: Array, y: Array, valid: Array,
                       dim: int, kernel: str) -> Array:
    p = _unpack(theta, dim)
    lml = log_marginal_likelihood_masked(x, y, valid, p, kernel)
    # weak log-normal priors keep the fit away from degenerate corners
    prior = (-0.5 * jnp.sum((p.log_lengthscale / 2.0) ** 2)
             - 0.5 * (p.log_amplitude / 2.0) ** 2
             - 0.5 * ((p.log_noise + 4.0) / 2.0) ** 2)
    return -(lml + prior)


@functools.partial(jax.jit, static_argnames=("dim", "kernel", "opts"))
def _fit_padded(x, y, valid, thetas, lower, upper, *, dim: int,
                kernel: str, opts: LbfgsbOptions):
    def single(theta):
        return _neg_map_objective(theta, x, y, valid, dim, kernel)

    vg = jax.vmap(jax.value_and_grad(single))
    res = lbfgsb_minimize(lambda tb: vg(tb), thetas, lower, upper, opts)
    theta_best = res.x[jnp.argmin(res.f)]
    p = _unpack(theta_best, dim)

    v = valid.astype(x.dtype)
    K = gram(x, p, kernel)
    K = K * (v[:, None] * v[None, :]) + jnp.diag(1.0 - v)
    L = jnp.linalg.cholesky(K)
    alpha = cho_solve((L, True), y * v)
    return theta_best, L, alpha, res.k


def fit_gp(
    x: Array,
    y: Array,
    *,
    kernel: str = "matern52",
    n_restarts: int = 2,
    init: Optional[KernelParams] = None,
    seed: int = 0,
    maxiter: int = 60,
    pad_bucket: int = PAD_BUCKET,
) -> GPState:
    """Fit kernel hyperparameters by MAP (multi-start, batched L-BFGS-B).

    Returns a GPState on the *padded* training set: padded α entries are 0
    and padded points sit at kernel-underflow distance, so `predict` is
    exact while every downstream consumer compiles once per size bucket.
    """
    n, dim = x.shape
    dt = x.dtype

    n_pad = (-n) % pad_bucket if pad_bucket else 0
    if n_pad:
        far = jnp.full((n_pad, dim), _FAR, dt) + \
            jnp.arange(n_pad, dtype=dt)[:, None]
        x = jnp.concatenate([x, far], 0)
        y = jnp.concatenate([y, jnp.zeros((n_pad,), dt)], 0)
    valid = (jnp.arange(n + n_pad) < n)

    base = init if init is not None else KernelParams(
        log_lengthscale=jnp.zeros((dim,), dt),
        log_amplitude=jnp.zeros((), dt),
        log_noise=jnp.asarray(-4.0, dt))
    theta0 = _pack(base)
    P = theta0.shape[0]

    key = jax.random.PRNGKey(seed)
    jitter0 = jax.random.uniform(key, (max(n_restarts - 1, 0), P), dt,
                                 minval=-1.0, maxval=1.0)
    thetas = jnp.concatenate([theta0[None], theta0[None] + jitter0], 0)

    lower = jnp.concatenate([
        jnp.full((dim,), LOG_LS_BOUNDS[0], dt),
        jnp.asarray([LOG_AMP_BOUNDS[0]], dt),
        jnp.asarray([LOG_NOISE_BOUNDS[0]], dt)])
    upper = jnp.concatenate([
        jnp.full((dim,), LOG_LS_BOUNDS[1], dt),
        jnp.asarray([LOG_AMP_BOUNDS[1]], dt),
        jnp.asarray([LOG_NOISE_BOUNDS[1]], dt)])

    opts = LbfgsbOptions(m=10, maxiter=maxiter, pgtol=1e-5, ftol=1e-12)
    theta_best, L, alpha, _ = _fit_padded(
        x, y, valid, thetas,
        jnp.broadcast_to(lower, thetas.shape),
        jnp.broadcast_to(upper, thetas.shape),
        dim=dim, kernel=kernel, opts=opts)

    return GPState(x_train=x, y_train=y, params=_unpack(theta_best, dim),
                   chol=L, alpha=alpha, kernel=kernel)


def standardize(y: Array) -> Tuple[Array, Array, Array]:
    """Return (y_std, mean, std) — GPSampler-style target standardization."""
    mu = jnp.mean(y)
    sd = jnp.maximum(jnp.std(y), 1e-10)
    return (y - mu) / sd, mu, sd
