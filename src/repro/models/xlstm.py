"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan).

mLSTM per head (d_k keys, d_v values), exponential gating with stabilizer:
    m_t = max(f̃_t + m_{t-1}, ĩ_t)
    f'_t = exp(f̃_t + m_{t-1} - m_t),  i'_t = exp(ĩ_t - m_t)
    C_t = f'_t C_{t-1} + i'_t v_t k_tᵀ        n_t = f'_t n_{t-1} + i'_t k_t
    h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)

Training uses the *chunkwise-parallel* form (lax.scan over chunks, O(L²+L·d²)
per chunk on the MXU); decode uses the O(1) recurrent step.  Sub-quadratic in
S ⇒ this family runs the long_500k cell (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import box, constrain
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, apply_norm

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel + recurrent step
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, logf, logi, chunk: int,
                    state: Optional[tuple] = None):
    """q/k: (B, H, S, dk); v: (B, H, S, dv); logf/logi: (B, H, S).

    Returns (h: (B, H, S, dv), final_state=(C, n, m)).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def resh(x, d=None):
        if d is None:
            return x.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)
        return x.reshape(B, H, nc, chunk, d).transpose(2, 0, 1, 3, 4)

    qs, ks, vs = resh(q, dk), resh(k, dk), resh(v, dv)
    lfs, lis = resh(logf), resh(logi)

    if state is None:
        cdt = jnp.promote_types(q.dtype, jnp.float32)
        C0 = jnp.zeros((B, H, dk, dv), cdt)
        n0 = jnp.zeros((B, H, dk), cdt)
        m0 = jnp.full((B, H), -1e30, cdt)
    else:
        C0, n0, m0 = state

    scale = dk ** -0.5

    def body(carry, xs):
        C, n, m = carry
        qc, kc, vc, lf, li = xs          # (B,H,L,*)
        L = qc.shape[2]
        bcum = jnp.cumsum(lf, axis=2)                       # (B,H,L)
        # intra-chunk log-decay D[t,s] = bcum_t - bcum_s + li_s (s ≤ t)
        ldec = bcum[..., :, None] - bcum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        ldec = jnp.where(tri, ldec, -jnp.inf)
        # stabilizers
        m_intra = jnp.max(ldec, axis=-1)                    # (B,H,L)
        m_inter = bcum + m[..., None]                       # (B,H,L)
        m_t = jnp.maximum(m_intra, m_inter)
        m_t = jnp.maximum(m_t, -1e30)

        dec = jnp.exp(ldec - m_t[..., None])                # (B,H,L,L)
        inter_w = jnp.exp(m_inter - m_t)                    # (B,H,L)

        pet = jnp.promote_types(qc.dtype, jnp.float32)
        s_qk = jnp.einsum("bhld,bhmd->bhlm", qc, kc,
                          preferred_element_type=pet) * scale
        h_num = jnp.einsum("bhlm,bhmv->bhlv", s_qk * dec, vc) \
            + inter_w[..., None] * jnp.einsum(
                "bhld,bhdv->bhlv", qc, C) * scale
        # normalizer state at t: decayed k-sum (no q): intra + carried n
        n_t = jnp.einsum("bhlm,bhmd->bhld", dec, kc) \
            + inter_w[..., None] * jnp.broadcast_to(
                n[:, :, None, :], (B, H, L, dk))
        qn = jnp.einsum("bhld,bhld->bhl", qc, n_t) * scale
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        h = h_num / denom[..., None]

        # chunk-final state
        lf_total = bcum[..., -1]                            # (B,H)
        m_new = jnp.maximum(lf_total + m, jnp.max(
            lf_total[..., None] - bcum + li, axis=-1))
        w_old = jnp.exp(lf_total + m - m_new)               # (B,H)
        w_s = jnp.exp(lf_total[..., None] - bcum + li - m_new[..., None])
        C_new = w_old[..., None, None] * C + jnp.einsum(
            "bhl,bhld,bhlv->bhdv", w_s, kc, vc)
        n_new = w_old[..., None] * n + jnp.einsum(
            "bhl,bhld->bhd", w_s, kc)
        return (C_new, n_new, m_new), h

    (Cf, nf, mf), hs = lax.scan(
        body, (C0, n0, m0), (qs, ks, vs, lfs, lis))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dv)
    return h, (Cf, nf, mf)


def mlstm_step(q, k, v, logf, logi, state):
    """Single decode step.  q/k: (B,H,dk); v: (B,H,dv); logf/logi: (B,H)."""
    C, n, m = state
    dk = q.shape[-1]
    scale = dk ** -0.5
    m_new = jnp.maximum(logf + m, logi)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(logi - m_new)
    C_new = fp[..., None, None] * C + ip[..., None, None] * \
        jnp.einsum("bhd,bhv->bhdv", k, v)
    n_new = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C_new) * scale
    qn = jnp.einsum("bhd,bhd->bh", q, n_new) * scale
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    return num / denom[..., None], (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM cell — strictly sequential scalar memory
# ---------------------------------------------------------------------------

def slstm_scan(z, i_in, f_in, o_in, r_z, r_i, r_f, r_o,
               state: Optional[tuple] = None):
    """Inputs: (B, S, W) pre-activations; r_*: (H, W/H, W/H) block-diagonal
    recurrent weights.  Returns (h: (B, S, W), final state)."""
    B, S, W = z.shape
    H = r_z.shape[0]
    wh = W // H

    if state is None:
        cdt = jnp.promote_types(z.dtype, jnp.float32)
        c0 = jnp.zeros((B, W), cdt)
        n0 = jnp.ones((B, W), cdt)
        h0 = jnp.zeros((B, W), cdt)
        m0 = jnp.zeros((B, W), cdt)
    else:
        c0, n0, h0, m0 = state

    def rmat(h, r):
        hb = h.reshape(B, H, wh)
        return jnp.einsum("bhw,hwu->bhu", hb, r).reshape(B, W)

    def step(carry, xs):
        c, n, h, m = carry
        zt, it, ft, ot = xs              # (B, W)
        zt = jnp.tanh(zt + rmat(h, r_z))
        it = it + rmat(h, r_i)
        ft = ft + rmat(h, r_f)
        ot = jax.nn.sigmoid(ot + rmat(h, r_o))
        m_new = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    cdt2 = jnp.promote_types(z.dtype, jnp.float32)
    xs = tuple(a.astype(cdt2).transpose(1, 0, 2)
               for a in (z, i_in, f_in, o_in))
    (cf, nf, hf, mf), hs = lax.scan(step, (c0, n0, h0, m0), xs)
    return hs.transpose(1, 0, 2).astype(z.dtype), (cf, nf, hf, mf)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    up = 2 * d
    H = cfg.n_heads
    dk = up // H // 2
    dv = up // H
    ks = jax.random.split(key, 8)
    return {
        "w_up": box(_dense_init(ks[0], (d, up), dtype, d), "embed", "lru"),
        "w_gate": box(_dense_init(ks[1], (d, up), dtype, d), "embed", "lru"),
        "conv_w": box(_dense_init(ks[2], (cfg.conv_width, up), dtype,
                                  cfg.conv_width), None, "lru"),
        "conv_b": box(jnp.zeros((up,), dtype), "lru"),
        "w_q": box(_dense_init(ks[3], (up, H, dk), dtype, up),
                   "lru", "heads", None),
        "w_k": box(_dense_init(ks[4], (up, H, dk), dtype, up),
                   "lru", "heads", None),
        "w_if": box(_dense_init(ks[5], (up, H, 2), jnp.float32, up),
                    "lru", "heads", None),
        "w_down": box(_dense_init(ks[6], (up, d), dtype, up),
                      "lru", "embed"),
        "skip_scale": box(jnp.ones((up,), dtype), "lru"),
    }


def apply_mlstm_block(p: dict, cfg: ModelConfig, x: Array,
                      state=None, *, decode: bool = False):
    """x: (B, S, D).  state: (conv_state, (C, n, m)) when decoding."""
    from repro.models.rglru import _causal_conv

    B, S, D = x.shape
    H = cfg.n_heads
    up = p["w_up"].value.shape[1]
    dv = up // H

    xu = jnp.einsum("bsd,du->bsu", x, p["w_up"].value)
    z = jnp.einsum("bsd,du->bsu", x, p["w_gate"].value)
    xu = constrain(xu, "batch", None, "lru")

    conv_state = state[0] if state is not None else None
    xc, new_conv = _causal_conv(xu, p["conv_w"].value, p["conv_b"].value,
                                conv_state)
    xc = jax.nn.silu(xc)

    q = jnp.einsum("bsu,uhk->bhsk", xc, p["w_q"].value)
    k = jnp.einsum("bsu,uhk->bhsk", xc, p["w_k"].value)
    v = xu.reshape(B, S, H, dv).transpose(0, 2, 1, 3)
    gates = jnp.einsum("bsu,uhg->bhsg", xc.astype(jnp.float32),
                       p["w_if"].value)
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])

    cell_state = state[1] if state is not None else None
    if decode:
        assert S == 1
        h, new_cell = mlstm_step(q[:, :, 0].astype(jnp.float32),
                                 k[:, :, 0].astype(jnp.float32),
                                 v[:, :, 0].astype(jnp.float32),
                                 logf[:, :, 0], logi[:, :, 0], cell_state)
        h = h[:, :, None, :]
    else:
        chunk = min(cfg.mlstm_chunk, S)
        h, new_cell = mlstm_chunkwise(q.astype(jnp.float32),
                                      k.astype(jnp.float32),
                                      v.astype(jnp.float32),
                                      logf, logi, chunk, cell_state)

    h = h.astype(xu.dtype).transpose(0, 2, 1, 3).reshape(B, S, up)
    h = h + xc * p["skip_scale"].value
    out = h * jax.nn.silu(z)
    y = jnp.einsum("bsu,ud->bsd", out, p["w_down"].value)
    y = constrain(y, "batch", None, None)
    new_state = (new_conv, new_cell) if state is not None else None
    return y, new_state


def init_slstm_block(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    wh = d // H
    ks = jax.random.split(key, 10)
    p = {"w_in": box(_dense_init(ks[0], (d, 4 * d), dtype, d),
                     "embed", "lru")}
    for i, name in enumerate(("r_z", "r_i", "r_f", "r_o")):
        p[name] = box(_dense_init(ks[1 + i], (H, wh, wh), jnp.float32, wh),
                      "heads", None, None)
    # post-cell GN-ish scale + FFN-lite projection
    p["w_out"] = box(_dense_init(ks[5], (d, d), dtype, d), "embed", None)
    return p


def apply_slstm_block(p: dict, cfg: ModelConfig, x: Array, state=None):
    B, S, D = x.shape
    pre = jnp.einsum("bsd,dz->bsz", x, p["w_in"].value)
    z, i_in, f_in, o_in = jnp.split(pre, 4, axis=-1)
    h, new_state = slstm_scan(z, i_in, f_in, o_in,
                              p["r_z"].value, p["r_i"].value,
                              p["r_f"].value, p["r_o"].value, state)
    y = jnp.einsum("bsd,de->bse", h.astype(x.dtype), p["w_out"].value)
    y = constrain(y, "batch", None, None)
    return y, (new_state if state is not None else None)


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype):
    up = 2 * cfg.d_model
    H = cfg.n_heads
    dk = up // H // 2
    dv = up // H
    conv = jnp.zeros((batch, cfg.conv_width - 1, up), dtype)
    cell = (jnp.zeros((batch, H, dk, dv), jnp.float32),
            jnp.zeros((batch, H, dk), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))
    return (conv, cell)


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.ones((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32))
