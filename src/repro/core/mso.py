"""Multi-start acquisition-function optimization — the paper's Algorithm 1/2.

Four strategies behind one API (`maximize_acqf`):

* ``seq``      — SEQ. OPT.: B sequential scipy L-BFGS-B runs (Algorithm 2).
* ``cbe``      — C-BE: one scipy L-BFGS-B over the flattened (B·D,) summed
                 objective (BoTorch ≤0.14 practice; off-diagonal artifacts).
* ``dbe``      — D-BE (paper): coroutine-decoupled scipy workers + batched
                 evaluation, shrinking active set.
* ``dbe_vec``  — D-BE vectorized (ours, beyond-paper): device-resident batched
                 L-BFGS-B (`core.lbfgsb`), one jitted program, zero host syncs.

All strategies *maximize* the acquisition function (internally minimizing its
negation, matching BoTorch/Optuna conventions).

Compilation discipline: the acquisition is passed as a *module-level pure
function* ``acq_fn(state, X) -> (k,)`` plus a pytree ``state`` (GP arrays,
incumbent, ...).  The jitted evaluators key their cache on the function
identity and shapes only, so a 300-trial BO run with size-bucketed GP states
compiles each strategy a handful of times total.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coroutine as co
from repro.core.lbfgsb import LbfgsbOptions, lbfgsb_minimize

Array = jax.Array

STRATEGIES = ("seq", "cbe", "dbe", "dbe_vec")

# acq_fn(state, X:(k,D)) -> (k,) acquisition values (maximization scale)
AcqStateFn = Callable[[Any, Array], Array]


@dataclass
class MsoOptions:
    m: int = 10                  # L-BFGS-B memory
    maxiter: int = 200           # per-restart iteration cap (paper setting)
    pgtol: float = 1e-2          # paper: ||∇α||_inf ≤ 1e-2
    maxls: int = 25
    ftol: float = 0.0            # disabled by default, like the paper


@dataclass
class MsoResult:
    x: np.ndarray                # (B, D) per-restart maximizers
    acq: np.ndarray              # (B,)  acquisition values (max scale)
    best_x: np.ndarray           # (D,)
    best_acq: float
    n_iters: np.ndarray          # (B,) QN iterations per restart
    n_evals: np.ndarray          # (B,) objective evals per restart
    n_rounds: int                # batched evaluation rounds (wall-clock proxy)
    wall_time: float
    strategy: str


# ---------------------------------------------------------------------------
# jitted evaluators (cache keyed on acq_fn identity + shapes)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=0)
def _neg_value_and_grad(acq_fn: AcqStateFn, state, X):
    f = -acq_fn(state, X)
    g = jax.grad(lambda Z: -jnp.sum(acq_fn(state, Z)))(X)
    return f, g


def make_neg_batch_eval(acq_fn: AcqStateFn, state,
                        pad_to: Optional[int] = None) -> co.BatchEvalFn:
    """numpy-facing batched (value, grad) evaluator of ``-acq``.

    When ``pad_to`` is given, smaller active sets are padded to a fixed batch
    so one compiled executable serves the whole shrinking schedule (this is
    what the paper's 'batch shrinks progressively' turns into under XLA's
    static shapes; `dbe_vec` measures the masked-lockstep alternative).
    """

    def batch_eval(X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        k, D = X.shape
        if pad_to is not None and k < pad_to:
            Xp = np.concatenate([X, np.repeat(X[-1:], pad_to - k, 0)], 0)
        else:
            Xp = X
        f, g = _neg_value_and_grad(acq_fn, state, jnp.asarray(Xp))
        return (np.asarray(f)[:k], np.asarray(g)[:k])

    return batch_eval


@functools.partial(jax.jit, static_argnums=(0, 5))
def _run_vectorized(acq_fn: AcqStateFn, state, x0, lower, upper,
                    opts: LbfgsbOptions):
    def fun_batched(X):
        f = -acq_fn(state, X)
        g = jax.grad(lambda Z: -jnp.sum(acq_fn(state, Z)))(X)
        return f, g

    return lbfgsb_minimize(fun_batched, x0, lower, upper, opts)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def maximize_acqf(
    acq_fn: AcqStateFn,
    x0: np.ndarray,
    lower: np.ndarray,
    upper: np.ndarray,
    *,
    acq_state: Any = None,
    strategy: str = "dbe",
    options: MsoOptions = MsoOptions(),
) -> MsoResult:
    """Run MSO with the chosen strategy.  ``x0``: (B, D) restart points.

    ``acq_fn(state, X)`` should be a module-level function for jit-cache
    reuse; pass per-trial data (fitted GP, incumbent) through ``acq_state``.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    x0 = np.asarray(x0, np.float64)
    B, D = x0.shape
    lower = np.broadcast_to(np.asarray(lower, np.float64), (D,))
    upper = np.broadcast_to(np.asarray(upper, np.float64), (D,))

    if strategy == "dbe_vec":
        opts = LbfgsbOptions(m=options.m, maxiter=options.maxiter,
                             pgtol=options.pgtol, ftol=options.ftol,
                             maxls=options.maxls)
        t0 = time.perf_counter()
        res = _run_vectorized(acq_fn, acq_state, jnp.asarray(x0),
                              jnp.asarray(np.broadcast_to(lower, (B, D))),
                              jnp.asarray(np.broadcast_to(upper, (B, D))),
                              opts)
        res = jax.tree.map(np.asarray, res)
        wall = time.perf_counter() - t0
        acq = -res.f
        best = int(np.argmax(acq))
        return MsoResult(x=res.x, acq=acq, best_x=res.x[best],
                         best_acq=float(acq[best]), n_iters=res.k,
                         n_evals=res.n_evals, n_rounds=int(res.rounds),
                         wall_time=wall, strategy="dbe_vec")

    batch_eval = make_neg_batch_eval(acq_fn, acq_state, pad_to=B)
    kw = dict(m=options.m, maxiter=options.maxiter, pgtol=options.pgtol,
              maxls=options.maxls, factr=0.0)
    t0 = time.perf_counter()
    if strategy == "seq":
        out = co.run_seq_opt(batch_eval, x0, lower, upper, **kw)
    elif strategy == "cbe":
        out = co.run_cbe(batch_eval, x0, lower, upper, **kw)
    else:
        out = co.run_dbe_coroutine(batch_eval, x0, lower, upper, **kw)
    wall = time.perf_counter() - t0

    acq = -out.f
    best = int(np.argmax(acq))
    return MsoResult(x=out.x, acq=acq, best_x=out.x[best],
                     best_acq=float(acq[best]), n_iters=out.n_iters,
                     n_evals=out.n_evals, n_rounds=out.n_rounds,
                     wall_time=wall, strategy=strategy)


def maximize_acqf_closure(acq_batched, x0, lower, upper, *,
                          strategy="dbe", options=MsoOptions()):
    """Convenience wrapper for plain closures ``X -> (k,)`` (tests/examples).
    Recompiles per closure identity — fine outside hot loops."""
    def fn(state, X):
        del state
        return acq_batched(X)
    return maximize_acqf(fn, x0, lower, upper, acq_state=None,
                         strategy=strategy, options=options)
