"""CLI: ``python -m repro.analysis [paths...] [options]``.

Exit status: 0 when every finding is fixed, inline-suppressed (with a
reason), or baselined (with a reason); 1 otherwise.  ``--check`` is the
CI entry point (identical semantics, kept explicit so workflows read
as intent).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import ALL_RULES, Baseline, Report, load_project, run_rules

# src/repro/analysis/__main__.py → repo root is parents[3]
REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_PATHS = ("src/repro", "benchmarks")
DEFAULT_BASELINE = "analysis_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant linter for the compile-economy, WAL, "
                    "donation, trace-discipline, and NaN contracts")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS} "
                         f"under the repo root)")
    ap.add_argument("--root", type=Path, default=REPO_ROOT,
                    help="repo root for relative paths in the report")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/"
                         f"{DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (show every finding)")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the full JSON report here")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: exit 1 on any open finding")
    ap.add_argument("--update-baseline", action="store_true",
                    help="append all open findings to the baseline with "
                         "reason=TODO (then edit in real reasons)")
    args = ap.parse_args(argv)

    root = args.root.resolve()
    paths = [Path(p) if Path(p).is_absolute() else root / p
             for p in (args.paths or DEFAULT_PATHS)]
    paths = [p for p in paths if p.exists()]
    if not paths:
        print("no input paths exist", file=sys.stderr)
        return 2

    # the tests/ exclusion guards the default sweep; a path the user
    # names explicitly (e.g. the lint fixtures) is always linted
    exclude = ("tests",) if not args.paths else ()
    project = load_project(paths, root, exclude=exclude)
    findings = run_rules(project, ALL_RULES)

    bpath = args.baseline or (root / DEFAULT_BASELINE)
    baseline = Baseline(path=bpath) if args.no_baseline \
        else Baseline.load(bpath)
    report = Report(project, findings, baseline)

    if args.update_baseline:
        for f in report.open:
            if f.rule == "baseline-missing-reason":
                continue
            baseline.entries.append(Baseline.entry_for(f, ""))
        baseline.save(bpath)
        print(f"wrote {len(report.open)} entries to {bpath}; "
              f"fill in the reasons (empty reasons fail the check)")
        return 0

    print(report.render())
    if args.json:
        report.write_json(args.json)
        print(f"\nJSON report: {args.json}")
    print(f"\nmodules={len(project.modules)} open={len(report.open)} "
          f"baselined={len(report.baselined)} "
          f"suppressed={len(report.suppressed)}")
    return 1 if report.failed else 0


if __name__ == "__main__":
    sys.exit(main())
