"""Metrics registry + the unified ``stats_snapshot()`` schema contract.

Two halves:

* **Registry** — counters, gauges, and fixed-bucket histograms with
  label attribution (``study=...``, ``tenant=...``) and Prometheus text
  exposition.  All host state; nothing here touches jax.
* **Schemas** — the one documented layout for the four engine-layer
  ``stats_snapshot()`` dicts (AskEngine, FleetEngine, FleetSampler,
  BOService) plus the EvalEngine block they compose over.  The layers
  nest by dict union (FleetSampler = EvalEngine ∪ FleetEngine ∪ fleet
  extras; BOService = FleetSampler ∪ ``svc_*``), which is exactly how
  the snapshots are built in code — :func:`validate_snapshot` checks an
  actual snapshot against the schema so the shapes can't silently drift
  again (the schema-shape test in ``tests/test_obs.py``).

:func:`ingest_snapshot` bridges the halves: it flattens any validated
snapshot into registry gauges (per-cause retrace counts, per-tenant
queue/served/shed series) so one Prometheus scrape exposes every layer.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Mapping[str, Any]]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotone counter; one value series per label set."""

    kind = "counter"

    def __init__(self, name: str, help_: str = ""):
        self.name = name
        self.help = help_
        self._values: Dict[LabelKey, float] = {}

    def inc(self, n: float = 1.0,
            labels: Optional[Mapping[str, Any]] = None) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + n

    def value(self, labels: Optional[Mapping[str, Any]] = None) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._values)


class Gauge(Counter):
    """Set-to-current-value metric (snapshot counters land here)."""

    kind = "gauge"

    def set(self, v: float,
            labels: Optional[Mapping[str, Any]] = None) -> None:
        self._values[_label_key(labels)] = float(v)

    def inc(self, n: float = 1.0,
            labels: Optional[Mapping[str, Any]] = None) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + n


# default latency buckets (milliseconds): 0.1ms .. ~100s, roughly 2.5x
DEFAULT_BUCKETS_MS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0,
                      10000.0, 100000.0)


class Histogram:
    """Fixed-bucket histogram with quantile derivation.

    Buckets are cumulative upper bounds (Prometheus ``le`` semantics);
    observations above the last bound land in the implicit +Inf bucket.
    Quantiles interpolate linearly within the winning bucket, which is
    as precise as fixed buckets allow — good enough for p50/p95/p99
    summary blocks, not a substitute for the raw latency deques the
    service keeps for its SLO controller.
    """

    kind = "histogram"

    def __init__(self, name: str, help_: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS_MS):
        self.name = name
        self.help = help_
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError(f"histogram {self.name}: no buckets")
        self._series: Dict[LabelKey, Dict[str, Any]] = {}

    def _cell(self, labels: Optional[Mapping[str, Any]]) -> Dict[str, Any]:
        key = _label_key(labels)
        cell = self._series.get(key)
        if cell is None:
            cell = {"counts": [0] * (len(self.bounds) + 1),
                    "sum": 0.0, "count": 0}
            self._series[key] = cell
        return cell

    def observe(self, v: float,
                labels: Optional[Mapping[str, Any]] = None) -> None:
        v = float(v)
        cell = self._cell(labels)
        i = len(self.bounds)                     # +Inf bucket by default
        for j, b in enumerate(self.bounds):
            if v <= b:
                i = j
                break
        cell["counts"][i] += 1
        cell["sum"] += v
        cell["count"] += 1

    def quantile(self, q: float,
                 labels: Optional[Mapping[str, Any]] = None
                 ) -> Optional[float]:
        cell = self._series.get(_label_key(labels))
        if cell is None or cell["count"] == 0:
            return None
        target = q * cell["count"]
        cum = 0
        for j, n in enumerate(cell["counts"]):
            if n == 0:
                continue
            if cum + n >= target:
                lo = 0.0 if j == 0 else self.bounds[j - 1]
                hi = self.bounds[j] if j < len(self.bounds) \
                    else self.bounds[-1]
                frac = (target - cum) / n
                return lo + frac * (hi - lo)
            cum += n
        return self.bounds[-1]

    def percentiles(self, labels: Optional[Mapping[str, Any]] = None
                    ) -> Dict[str, Optional[float]]:
        return {"p50": self.quantile(0.50, labels),
                "p95": self.quantile(0.95, labels),
                "p99": self.quantile(0.99, labels)}

    def series(self) -> Dict[LabelKey, Dict[str, Any]]:
        return {k: dict(v) for k, v in self._series.items()}


class MetricsRegistry:
    """Named metric store with Prometheus text exposition."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help_: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help_, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS_MS
                  ) -> Histogram:
        return self._get(Histogram, name, help_, buckets=buckets)

    def render_prometheus(self) -> str:
        """Prometheus text exposition format, metrics sorted by name."""
        out: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                out.append(f"# HELP {name} {m.help}")
            out.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, cell in sorted(m.series().items()):
                    cum = 0
                    for j, b in enumerate(m.bounds):
                        cum += cell["counts"][j]
                        lk = _label_key(dict(key, le=f"{b:g}"))
                        out.append(f"{name}_bucket"
                                   f"{_render_labels(lk)} {cum}")
                    lk = _label_key(dict(key, le="+Inf"))
                    out.append(f"{name}_bucket{_render_labels(lk)} "
                               f"{cell['count']}")
                    out.append(f"{name}_sum{_render_labels(key)} "
                               f"{cell['sum']:g}")
                    out.append(f"{name}_count{_render_labels(key)} "
                               f"{cell['count']}")
            else:
                for key, v in sorted(m.series().items()):
                    out.append(f"{name}{_render_labels(key)} {v:g}")
        return "\n".join(out) + "\n"


# --------------------------------------------------------------- schemas
#
# The documented snapshot layout.  Each entry lists the exact top-level
# keys a layer's stats_snapshot() returns; composite layers are built by
# union, mirroring the dict-union construction in code.  ``optional``
# keys appear only in some configurations (journaled planes).

RETRACES_KEYS = frozenset({"causes", "by_program"})

EVAL_ENGINE_KEYS = frozenset({
    "n_compiles", "n_eval_compiles", "n_lockstep_compiles", "n_rounds",
    "n_points", "n_padded", "n_refit_fallbacks", "bucket_rounds",
    "retraces"})

ASK_ENGINE_KEYS = frozenset({
    "n_full_refits", "n_incremental", "n_fallbacks", "n_full_compiles",
    "n_incr_compiles", "n_ask_compiles", "retraces"})

FLEET_ENGINE_KEYS = frozenset({
    "n_studies", "n_blocks", "n_full_refits", "n_incremental",
    "n_fallbacks", "n_steps", "n_admissions", "n_migrations",
    "n_migrations_intra", "n_migrations_cross", "n_rejected", "n_shed",
    "n_quarantined", "n_parked", "n_retries", "n_retry_backoffs",
    "backoff_total_s", "n_devices", "slots_per_device", "queue_depth",
    "n_full_compiles", "n_incr_compiles", "n_mso_compiles",
    "n_fleet_compiles", "retraces"})

FLEET_SAMPLER_KEYS = (EVAL_ENGINE_KEYS | FLEET_ENGINE_KEYS
                      | frozenset({"n_degraded"}))

SERVICE_KEYS = frozenset({
    "svc_rung", "svc_queue_depth", "svc_completed", "svc_shed",
    "svc_deadline_miss", "svc_rejected", "svc_retries",
    "svc_rung_changes", "svc_watchdog_alarms", "svc_p99_s",
    "svc_tenants"})

TENANT_KEYS = frozenset({
    "weight", "queue", "submitted", "served", "shed", "deadline_miss",
    "rejected", "bad_tells", "retries", "degraded", "is_shed"})

SNAPSHOT_SCHEMAS: Dict[str, Dict[str, frozenset]] = {
    "eval_engine": {"required": EVAL_ENGINE_KEYS,
                    "optional": frozenset()},
    "ask_engine": {"required": ASK_ENGINE_KEYS,
                   "optional": frozenset()},
    "fleet_engine": {"required": FLEET_ENGINE_KEYS,
                     "optional": frozenset()},
    # journal_seq appears iff the plane is journaled
    "fleet_sampler": {"required": FLEET_SAMPLER_KEYS,
                      "optional": frozenset({"journal_seq"})},
    "bo_service": {"required": FLEET_SAMPLER_KEYS | SERVICE_KEYS,
                   "optional": frozenset({"journal_seq"})},
}


def validate_snapshot(component: str, snap: Mapping[str, Any]
                      ) -> List[str]:
    """Structural check of a ``stats_snapshot()`` dict against the
    documented schema.  Returns a list of error strings (empty = valid):
    missing keys, unexpected keys, malformed ``retraces`` / tenant
    sub-blocks."""
    schema = SNAPSHOT_SCHEMAS.get(component)
    if schema is None:
        return [f"unknown component {component!r} "
                f"(know {sorted(SNAPSHOT_SCHEMAS)})"]
    errors: List[str] = []
    keys = set(snap.keys())
    missing = schema["required"] - keys
    extra = keys - schema["required"] - schema["optional"]
    if missing:
        errors.append(f"{component}: missing keys {sorted(missing)}")
    if extra:
        errors.append(f"{component}: unexpected keys {sorted(extra)}")
    rt = snap.get("retraces")
    if "retraces" in schema["required"] and isinstance(rt, Mapping):
        if set(rt.keys()) != RETRACES_KEYS:
            errors.append(f"{component}: retraces keys "
                          f"{sorted(rt.keys())} != {sorted(RETRACES_KEYS)}")
    elif "retraces" in schema["required"] and rt is not None:
        errors.append(f"{component}: retraces is {type(rt).__name__}, "
                      f"expected mapping")
    tenants = snap.get("svc_tenants")
    if "svc_tenants" in keys and isinstance(tenants, Mapping):
        for name, t in tenants.items():
            tk = set(t.keys())
            if tk != TENANT_KEYS:
                errors.append(
                    f"{component}: tenant {name!r} keys differ: "
                    f"missing {sorted(TENANT_KEYS - tk)}, "
                    f"extra {sorted(tk - TENANT_KEYS)}")
    return errors


def ingest_snapshot(registry: MetricsRegistry, component: str,
                    snap: Mapping[str, Any],
                    labels: Optional[Mapping[str, Any]] = None) -> None:
    """Flatten a (validated) snapshot into registry gauges.

    Scalar numeric keys become ``repro_<key>`` gauges labeled with
    ``component`` (+ caller labels, e.g. ``study=3``); retrace causes
    become a per-cause series; ``svc_tenants`` becomes per-tenant
    series for the numeric tenant fields.  Snapshots are cumulative, so
    re-ingesting simply overwrites — scrape-friendly.
    """
    base = dict(labels or {}, component=component)
    for key, v in snap.items():
        if isinstance(v, bool) or key == "svc_rung":
            continue
        if isinstance(v, (int, float)) and v is not None:
            registry.gauge(f"repro_{key}").set(v, labels=base)
    rt = snap.get("retraces")
    if isinstance(rt, Mapping):
        g = registry.gauge("repro_retraces",
                           "XLA traces by classified cause")
        for cause, n in rt.get("causes", {}).items():
            g.set(n, labels=dict(base, cause=cause))
    tenants = snap.get("svc_tenants")
    if isinstance(tenants, Mapping):
        for name, t in tenants.items():
            for key, v in t.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                registry.gauge(f"repro_tenant_{key}").set(
                    v, labels=dict(base, tenant=name))
    if isinstance(snap.get("svc_rung"), str):
        registry.gauge("repro_svc_rung_index",
                       "overload rung (0=admit .. 3=shed_tenant)").set(
            ["admit", "reject", "degrade",
             "shed_tenant"].index(snap["svc_rung"]), labels=base)
