"""GP hyperparameter fitting: MAP over log-parameters with our own batched
L-BFGS-B — the framework eats its own dog food: the GP fit itself is a
multi-start bound-constrained QN problem and runs through `core.lbfgsb`.

Compilation discipline: observations are padded to size *buckets* and the
whole fit (multi-start solver + final Cholesky) is one module-level jitted
function taking data as *arguments* — so a 300-trial BO run compiles the fit
a handful of times (once per bucket), not 300 times.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_solve

from repro.core.lbfgsb import LbfgsbOptions, lbfgsb_minimize
from repro.gp.gpr import (GPState, cholesky_update, kinv_update,
                          log_marginal_likelihood_masked)
from repro.gp.kernels import KERNELS, KernelParams, gram

Array = jax.Array

# Bounds on the log-hyperparameters (unit-cube-normalized x, standardized y).
LOG_LS_BOUNDS = (-4.0, 4.0)
LOG_AMP_BOUNDS = (-6.0, 6.0)
LOG_NOISE_BOUNDS = (-10.0, 2.0)

PAD_BUCKET = 32
_FAR = 1e6          # padded pseudo-points live this far away (kernel → 0)


def pad_bucket_for(n: int, pad: int) -> int:
    """Smallest pad bucket (multiple of ``pad``) holding ``n`` training
    points; ``pad=0`` disables bucketing.  THE bucketing rule for GP
    training sets — ``fit_gp``, the fused ask pipeline, and the
    benchmarks must all agree on it or the bit-identity and
    compile-count guarantees break."""
    return ((n + pad - 1) // pad) * pad if pad else n


def _pack(p: KernelParams) -> Array:
    return jnp.concatenate([p.log_lengthscale,
                            p.log_amplitude[None], p.log_noise[None]])


def _unpack(theta: Array, dim: int) -> KernelParams:
    return KernelParams(log_lengthscale=theta[:dim],
                        log_amplitude=theta[dim],
                        log_noise=theta[dim + 1])


# public names for the packed-θ representation (the fused ask pipeline
# carries θ across trials as a flat vector)
pack_theta = _pack
unpack_theta = _unpack


def _neg_map_objective(theta: Array, x: Array, y: Array, valid: Array,
                       dim: int, kernel: str) -> Array:
    p = _unpack(theta, dim)
    lml = log_marginal_likelihood_masked(x, y, valid, p, kernel)
    # weak log-normal priors keep the fit away from degenerate corners
    prior = (-0.5 * jnp.sum((p.log_lengthscale / 2.0) ** 2)
             - 0.5 * (p.log_amplitude / 2.0) ** 2
             - 0.5 * ((p.log_noise + 4.0) / 2.0) ** 2)
    return -(lml + prior)


def fit_padded_core(x, y, valid, thetas, lower, upper, *, dim: int,
                    kernel: str, opts: LbfgsbOptions):
    """Unjitted multi-start MAP fit on a padded/masked training set.

    Exposed (in addition to the jitted module-level wrapper below) so the
    fused ask program (`engine/ask.py`) can inline the exact same fit into
    its one-program suggest pipeline.
    """
    def single(theta):
        return _neg_map_objective(theta, x, y, valid, dim, kernel)

    vg = jax.vmap(jax.value_and_grad(single))
    res = lbfgsb_minimize(lambda tb: vg(tb), thetas, lower, upper, opts)
    theta_best = res.x[jnp.argmin(res.f)]
    p = _unpack(theta_best, dim)

    v = valid.astype(x.dtype)
    K = gram(x, p, kernel)
    K = K * (v[:, None] * v[None, :]) + jnp.diag(1.0 - v)
    L = jnp.linalg.cholesky(K)
    alpha = cho_solve((L, True), y * v)
    return theta_best, L, alpha, res.k


_fit_padded = jax.jit(fit_padded_core,
                      static_argnames=("dim", "kernel", "opts"))


def theta_bounds(dim: int, dtype) -> Tuple[Array, Array]:
    """(lower, upper) box bounds on the packed log-hyperparameters (P,)."""
    lower = jnp.concatenate([
        jnp.full((dim,), LOG_LS_BOUNDS[0], dtype),
        jnp.asarray([LOG_AMP_BOUNDS[0]], dtype),
        jnp.asarray([LOG_NOISE_BOUNDS[0]], dtype)])
    upper = jnp.concatenate([
        jnp.full((dim,), LOG_LS_BOUNDS[1], dtype),
        jnp.asarray([LOG_AMP_BOUNDS[1]], dtype),
        jnp.asarray([LOG_NOISE_BOUNDS[1]], dtype)])
    return lower, upper


def theta_init_grid(dim: int, dtype, n_restarts: int, seed: int,
                    init: Optional[KernelParams] = None) -> Array:
    """(n_restarts, P) multi-start θ inits — fit_gp's exact construction,
    exposed so the fused ask path reproduces the unfused fit bit-for-bit
    (same seed ⇒ same jitter draws ⇒ same starting simplex)."""
    base = init if init is not None else KernelParams(
        log_lengthscale=jnp.zeros((dim,), dtype),
        log_amplitude=jnp.zeros((), dtype),
        log_noise=jnp.asarray(-4.0, dtype))
    theta0 = _pack(base)
    P = theta0.shape[0]
    key = jax.random.PRNGKey(seed)
    jitter0 = jax.random.uniform(key, (max(n_restarts - 1, 0), P), dtype,
                                 minval=-1.0, maxval=1.0)
    return jnp.concatenate([theta0[None], theta0[None] + jitter0], 0)


FIT_OPTS = LbfgsbOptions(m=10, maxiter=60, pgtol=1e-5, ftol=1e-12)


def fit_gp(
    x: Array,
    y: Array,
    *,
    kernel: str = "matern52",
    n_restarts: int = 2,
    init: Optional[KernelParams] = None,
    seed: int = 0,
    maxiter: int = 60,
    pad_bucket: int = PAD_BUCKET,
) -> GPState:
    """Fit kernel hyperparameters by MAP (multi-start, batched L-BFGS-B).

    Returns a GPState on the *padded* training set: padded α entries are 0
    and padded points sit at kernel-underflow distance, so `predict` is
    exact while every downstream consumer compiles once per size bucket.
    """
    n, dim = x.shape
    dt = x.dtype

    n_pad = pad_bucket_for(n, pad_bucket) - n
    if n_pad:
        far = jnp.full((n_pad, dim), _FAR, dt) + \
            jnp.arange(n_pad, dtype=dt)[:, None]
        x = jnp.concatenate([x, far], 0)
        y = jnp.concatenate([y, jnp.zeros((n_pad,), dt)], 0)
    valid = (jnp.arange(n + n_pad) < n)

    thetas = theta_init_grid(dim, dt, n_restarts, seed, init=init)
    lower, upper = theta_bounds(dim, dt)

    opts = FIT_OPTS._replace(maxiter=maxiter)
    theta_best, L, alpha, _ = _fit_padded(
        x, y, valid, thetas,
        jnp.broadcast_to(lower, thetas.shape),
        jnp.broadcast_to(upper, thetas.shape),
        dim=dim, kernel=kernel, opts=opts)

    return GPState(x_train=x, y_train=y, params=_unpack(theta_best, dim),
                   chol=L, alpha=alpha, kernel=kernel)


def standardize(y: Array) -> Tuple[Array, Array, Array]:
    """Return (y_std, mean, std) — GPSampler-style target standardization."""
    mu = jnp.mean(y)
    sd = jnp.maximum(jnp.std(y), 1e-10)
    return (y - mu) / sd, mu, sd


def standardize_masked(y: Array, valid: Array) -> Tuple[Array, Array, Array]:
    """Masked :func:`standardize` over a padded target vector.

    Moments use only ``valid`` entries; padded slots come back exactly 0
    (the padded-fit convention).  Matches ``standardize`` on the valid
    subset, which keeps the fused ask program's fit input identical to
    the host pipeline's ``concat(standardize(y), zeros)``.
    """
    v = valid.astype(y.dtype)
    n = jnp.sum(v)
    mu = jnp.sum(y * v) / n
    sd = jnp.maximum(jnp.sqrt(jnp.sum((y - mu) ** 2 * v) / n), 1e-10)
    return jnp.where(valid, (y - mu) / sd, 0.0), mu, sd


def incremental_update(
    x: Array,
    y_std: Array,
    n_valid: Array,
    params: KernelParams,
    chol: Array,
    kinv: Optional[Array] = None,
    *,
    kernel: str = "matern52",
    jitter: float = 1e-8,
) -> Tuple[Array, Array, Optional[Array], Array]:
    """O(n²) trial-to-trial GP refit: fixed θ, one appended observation.

    ``chol`` (and optionally ``kinv``) describe the previous trial's
    padded fit over the first ``n_valid − 1`` rows of ``x``; the new
    observation sits at row ``n_valid − 1`` (inside the same pad bucket).
    Rank-one-updates the Cholesky factor / K⁻¹ and re-solves α for the
    (re-standardized) targets — everything O(n²), no Cholesky
    refactorization, no MAP optimization.

    Returns ``(chol, alpha, kinv, ok)``.  ``ok=False`` flags a
    numerically impossible Schur complement (duplicate point at zero
    noise, θ drifted badly): callers must then fall back to a full refit.
    """
    b = x.shape[0]
    idx = n_valid - 1
    dt = x.dtype
    valid_old = (jnp.arange(b) < idx).astype(dt)
    x_new = x[idx]
    k_col = KERNELS[kernel](x_new[None], x, params)[0] * valid_old
    k_diag = params.amplitude + params.noise + jitter
    chol_new, s = cholesky_update(chol, k_col, k_diag, idx)
    ok = jnp.isfinite(s) & (s > 1e-12 * k_diag)
    # y re-standardizes every trial (mean/std shift), so α is fresh either
    # way — but cho_solve on the updated factor is O(n²), not O(n³)
    alpha = cho_solve((chol_new, True), y_std)
    kinv_new = None if kinv is None else kinv_update(kinv, k_col, s, idx)
    return chol_new, alpha, kinv_new, ok
