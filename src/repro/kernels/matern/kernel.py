"""Matérn-5/2 gram matrix as a Pallas TPU kernel.

Hot spot: the O(n²D) gram construction inside every GP fit step (the fit's
L-BFGS-B evaluates the marginal likelihood dozens of times) and the (q, n)
cross-gram inside every batched acquisition evaluation — the cost the
paper's §4 model says dominates MSO.

TPU mapping: tiles of (TILE_M, TILE_N) outputs are produced per grid step;
each step loads an (TILE_M, D) and (TILE_N, D) slab of pre-scaled points
into VMEM and forms -2·a·bᵀ on the MXU, then applies the Matérn polynomial
on the VPU.  D is kept whole per block (BO dims are small); M/N tiles are
128-aligned for lane efficiency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 2.2360679774997896

TILE_M = 128
TILE_N = 128


def _matern_kernel(a_ref, b_ref, asq_ref, bsq_ref, amp_ref, out_ref):
    """One (TILE_M, TILE_N) block of the gram matrix.

    a_ref: (TILE_M, D) pre-scaled rows; b_ref: (TILE_N, D);
    asq_ref/bsq_ref: (TILE_M, 1)/(TILE_N, 1) squared norms; amp_ref: (1, 1).
    """
    a = a_ref[...]
    b = b_ref[...]
    # MXU: (M, D) @ (D, N)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = asq_ref[...] + bsq_ref[...].T - 2.0 * ab
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-36)
    poly = 1.0 + SQRT5 * r + (5.0 / 3.0) * d2
    out_ref[...] = (amp_ref[0, 0] * poly * jnp.exp(-SQRT5 * r)
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matern52_gram(x1: jax.Array, x2: jax.Array, inv_lengthscale: jax.Array,
                  amplitude: jax.Array, *, interpret: bool = False
                  ) -> jax.Array:
    """Pallas Matérn-5/2 cross gram, padded to tile multiples.

    Returns (n1, n2) in x1.dtype.  Use ``interpret=True`` off-TPU.
    """
    n1, d = x1.shape
    n2 = x2.shape[0]
    dtype = x1.dtype

    a = (x1 * inv_lengthscale).astype(jnp.float32)
    b = (x2 * inv_lengthscale).astype(jnp.float32)

    m_pad = (-n1) % TILE_M
    n_pad = (-n2) % TILE_N
    a = jnp.pad(a, ((0, m_pad), (0, 0)))
    b = jnp.pad(b, ((0, n_pad), (0, 0)))
    asq = jnp.sum(a * a, -1, keepdims=True)                 # (M, 1)
    bsq = jnp.sum(b * b, -1, keepdims=True)                 # (N, 1)
    amp = jnp.asarray(amplitude, jnp.float32).reshape(1, 1)

    M, N = a.shape[0], b.shape[0]
    grid = (M // TILE_M, N // TILE_N)

    out = pl.pallas_call(
        _matern_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, d), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_M, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b, asq, bsq, amp)

    return out[:n1, :n2].astype(dtype)
