"""Unified telemetry plane: tracing, metrics, and flight-recorder export.

Three host-only modules threaded through every engine layer (EvalEngine
→ AskEngine → FleetEngine → FleetSampler → BOService):

* :mod:`repro.obs.trace` — process-global span tracer (ring-buffered
  spans + instants, zero-cost no-op when disabled) and the
  ``ProgramTimer`` block-until-ready wrapper for device programs;
* :mod:`repro.obs.metrics` — counters/gauges/histograms with label
  attribution, Prometheus exposition, and the documented
  ``stats_snapshot()`` schema contract (``validate_snapshot``);
* :mod:`repro.obs.export` — Chrome-trace/Perfetto JSON from live
  traces, timeline reconstruction from WAL journals, and the
  per-phase latency breakdowns the BENCH writers embed.

Contract (ROADMAP invariant): all obs state is host-side, off by
default, and enabling it never changes what XLA compiles.

``export`` is intentionally not imported here — it pulls in
``repro.bo.journal``; import it explicitly (``from repro.obs import
export``) where needed so ``repro.obs.trace`` stays importable from the
lowest engine layers without cycles.
"""
from repro.obs import metrics, trace  # noqa: F401
from repro.obs.metrics import (MetricsRegistry, SNAPSHOT_SCHEMAS,  # noqa: F401
                               ingest_snapshot, validate_snapshot)
from repro.obs.trace import (ProgramTimer, Tracer, disable,  # noqa: F401
                             enable, enabled, get, instant, span)
