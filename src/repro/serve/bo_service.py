"""BO-as-a-service: an async multi-tenant ask/tell front end on the fleet.

The fleet plane (PRs 3/6/7) made batched suggests cheap, durable, and
crash-recoverable — but it is still driven like a benchmark: one caller,
synchronized rounds.  The north-star traffic shape (ROADMAP item 3) is
the opposite: many *tenants* issuing interleaved ask/tell calls at their
own rates, with their own latency expectations, some of them misbehaving.
:class:`BOService` is the missing service loop — a long-lived,
single-threaded event loop over :class:`~repro.bo.sampler.FleetSampler`
that turns raw fleet steps into a served workload with QoS:

* **per-tenant fair queues** — ask requests queue per tenant and are
  dispatched under deficit-round-robin weighted fair scheduling
  (``TenantConfig.weight``): each scheduling round refills every active
  tenant's deficit by ``quantum x weight`` and serves requests (cost 1)
  while the deficit lasts, so one tenant's flood changes only its own
  queueing delay.  Tells are validated (non-finite refused — NaN-tell
  spam costs the spammer a synchronous ``ValueError`` and nobody else
  anything) and applied immediately: they are O(1) host appends and feed
  the next ask's observation sync.
* **per-request deadlines** — every ask carries a deadline budget
  (per-request override or the tenant default).  A request whose
  deadline passes while queued is shed before it costs a dispatch; one
  that comes back late is shed on completion.  Either way the shed is
  journaled and the fleet-side slot reservation is cancelled
  (:meth:`FleetSampler.cancel_ask`) — suggest keys derive from the trial
  count, so cancellation is deterministic to undo.
* **bounded retry backoff** — a transient dispatch failure (an isolated
  per-study exception from the batch, or an injected transient-refit
  veto) re-queues the request with bounded exponential backoff plus
  deterministic jitter, up to ``max_retries`` attempts, each journaled.
* **overload ladder** — queue depth and a rolling p99 latency estimate
  drive a four-rung ladder, each transition journaled:
  ``admit`` → ``reject`` (new asks refused with
  :class:`~repro.engine.FleetFullError` naming the reason) →
  ``degrade`` (the lowest-weight tenant's studies leave the fleet for
  the solo :class:`~repro.engine.ask.AskEngine` path, freeing slots but
  staying served) → ``shed_tenant`` (the lowest-weight tenant is dropped
  entirely, its queue failed with :class:`TenantShedError`).
* **watchdog + drain** — :meth:`install_watchdog` arms the PR-7 SIGTERM
  flag; the loop polls it and drains at a request boundary: the pending
  queue is journaled (``svc_drain``), outstanding futures fail with
  :class:`ServiceDraining`, and :meth:`FleetSampler.drain` checkpoints
  and closes the journal.  Slow steps past ``watchdog_slow_step`` are
  journaled as ``svc_watchdog`` alarms.
* **recovery** — every service-visible transition (accept, dispatch,
  done, shed, retry, rung change, degrade, tenant shed, drain) is
  journaled *before* it takes effect, through the same
  :class:`~repro.bo.journal.StudyJournal` the fleet uses.
  :meth:`BOService.recover` rebuilds the fleet via
  :meth:`FleetSampler.recover`, then replays the service records into a
  request ledger: requests that never dispatched re-enter their tenant
  queues in order; requests whose ask was journaled but never delivered
  come back as ready results.  At ``refit_interval=1`` the restored
  pending queue — and every suggestion it goes on to produce — is
  bitwise identical to the uninterrupted run at any kill offset.

Everything here is host-side scheduling over the same <=3 compiled fleet
programs per (bucket, slots) shape: no program keys on tenant, overload
rung, deadline, or recovery state (the PR-7 "faults never reach traced
code" invariant, extended to the service plane).  Time comes from an
injectable clock (``now()``/``sleep()``), so the whole control surface
runs under a virtual clock in tests — deadlines, backoff, and watchdog
behavior are deterministic, never wall-clock-flaky.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.bo.sampler import FleetSampler, Trial
from repro.engine import FleetFullError
from repro.obs import trace as obs

RUNGS = ("admit", "reject", "degrade", "shed_tenant")

# Per-tenant latency history cap: large enough that benchmark-scale runs
# keep every sample for exact p50/p99, bounded so a long-lived service
# deployment doesn't leak memory proportional to requests served.
TENANT_LATENCY_CAP = 65536


class DeadlineExceeded(RuntimeError):
    """The request's deadline budget ran out (shed while queued, or the
    suggestion came back late); journaled as ``svc_shed``."""


class TenantShedError(RuntimeError):
    """The tenant was shed by the overload ladder (or never existed any
    more): its queued requests fail and new submissions are refused."""


class ServiceDraining(RuntimeError):
    """The service is draining (SIGTERM watchdog): outstanding requests
    fail but stay journaled, so recovery restores them."""


class RequestFailed(RuntimeError):
    """The request exhausted its transient-failure retry budget."""


@dataclass(frozen=True)
class TenantConfig:
    """One tenant: a named owner of fleet studies with a QoS contract."""
    name: str
    weight: float = 1.0              # DRR share (relative)
    studies: Tuple[int, ...] = ()    # FleetSampler study indices owned
    deadline: Optional[float] = None  # default per-ask budget (seconds)

    def __post_init__(self):
        if self.weight <= 0.0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")


@dataclass(frozen=True)
class OverloadConfig:
    """Ladder thresholds.  Depth counts queued+delayed asks; the p99
    rungs compare the rolling completion-latency estimate to the SLO,
    and apply only while a backlog exists — the estimate refreshes on
    completions, so with an empty queue it is stale by construction and
    must not pin the service at reject."""
    reject_depth: int = 64           # rung 1: refuse new asks
    degrade_depth: int = 128         # rung 2: degrade lowest-weight tenant
    shed_depth: int = 256            # rung 3: shed lowest-weight tenant
    p99_slo: Optional[float] = None  # seconds; None disables p99 rungs
    tenant_queue_cap: Optional[int] = None   # per-tenant backlog cap
    window: int = 256                # latency samples in the p99 window
    min_samples: int = 20            # need this many before p99 counts


class _SystemClock:
    now = staticmethod(time.monotonic)
    sleep = staticmethod(time.sleep)


class _Request:
    """One ask request's lifecycle record (the sync-core 'future')."""

    __slots__ = ("rid", "tenant", "study", "submit_t", "deadline", "state",
                 "result", "error", "attempts", "not_before", "done_t",
                 "event")

    def __init__(self, rid: int, tenant: str, study: int, submit_t: float,
                 deadline: Optional[float]):
        self.rid = rid
        self.tenant = tenant
        self.study = study
        self.submit_t = submit_t
        self.deadline = deadline         # absolute service-clock time
        self.state = "queued"   # queued|delayed|dispatched|done|shed|failed
        self.result: Optional[Trial] = None
        self.error: Optional[BaseException] = None
        self.attempts = 0
        self.not_before: Optional[float] = None   # backoff eligibility
        self.done_t: Optional[float] = None
        self.event: Optional[asyncio.Event] = None   # async waiter, if any

    @property
    def done(self) -> bool:
        return self.state in ("done", "shed", "failed")

    def _wake(self) -> None:
        """Wake the async waiter (if one attached) after a terminal
        state transition.  Every code path that sets a terminal state
        must call this, or an :meth:`BOService.ask` coroutine waits
        forever."""
        if self.event is not None:
            self.event.set()


@dataclass
class _TenantState:
    cfg: TenantConfig
    queue: Deque[_Request] = field(default_factory=deque)
    deficit: float = 0.0
    shed: Optional[str] = None       # ladder rung 3 reason
    degraded: Optional[str] = None   # ladder rung 2 reason
    # per-tenant service stats (all service-visible QoS accounting)
    n_submitted: int = 0
    n_served: int = 0
    n_shed: int = 0
    n_deadline_miss: int = 0
    n_rejected: int = 0
    n_bad_tells: int = 0
    n_retries: int = 0
    latencies: Deque[float] = field(
        default_factory=lambda: deque(maxlen=TENANT_LATENCY_CAP))


class BOService:
    """Single-threaded async ask/tell service loop over a FleetSampler.

    The sync core (`submit_ask` / `submit_tell` / `service_step`) is the
    whole state machine — tests and benchmarks drive it directly, under
    a virtual clock when determinism matters.  The async facade
    (:meth:`ask` / :meth:`tell` / :meth:`run`) wraps it for coroutine
    clients sharing one event loop with the server task.

    Every study index in ``fs`` must be owned by exactly one tenant.
    Journaling (and therefore :meth:`recover`) requires the sampler to
    have been built with ``journal_dir``.
    """

    def __init__(self, fs: FleetSampler, tenants: List[TenantConfig], *,
                 overload: Optional[OverloadConfig] = None,
                 quantum: float = 1.0,
                 max_batch: Optional[int] = None,
                 max_retries: int = 3,
                 backoff_base: float = 0.05,
                 backoff_cap: float = 1.0,
                 backoff_jitter: float = 0.25,
                 watchdog_slow_step: Optional[float] = None,
                 clock=None, _recovering: bool = False):
        self.fs = fs
        self.overload = overload if overload is not None else OverloadConfig()
        self.quantum = float(quantum)
        self.max_batch = max_batch
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.backoff_jitter = float(backoff_jitter)
        self.watchdog_slow_step = watchdog_slow_step
        self.clock = clock if clock is not None else _SystemClock()
        if clock is not None:
            # one time base: fleet-side backoff/latency sleeps charge the
            # same (possibly virtual) clock the service schedules on
            fs.fleet._sleep = self.clock.sleep
        self._backoff_rng = np.random.default_rng(0x5E)
        self._tenants: Dict[str, _TenantState] = {}
        self._order: List[str] = []
        self._study_owner: Dict[int, str] = {}
        for tc in tenants:
            if tc.name in self._tenants:
                raise ValueError(f"duplicate tenant {tc.name!r}")
            for s in tc.studies:
                if not 0 <= s < len(fs):
                    raise ValueError(
                        f"tenant {tc.name!r}: study {s} out of range "
                        f"(fleet has {len(fs)})")
                if s in self._study_owner:
                    raise ValueError(
                        f"study {s} owned by both "
                        f"{self._study_owner[s]!r} and {tc.name!r}")
                self._study_owner[s] = tc.name
            self._tenants[tc.name] = _TenantState(cfg=tc)
            self._order.append(tc.name)
        self._delayed: List[_Request] = []   # backoff'd, awaiting retry
        self._req_seq = 0
        self._rung = 0
        self._rung_reason = ""
        self._lat: Deque[float] = deque(maxlen=self.overload.window)
        self._draining = False
        self._stopped = False
        self._preempt = None
        # service counters (rolled into stats_snapshot)
        self.n_completed = 0
        self.n_shed = 0
        self.n_deadline_miss = 0
        self.n_rejected = 0
        self.n_retries = 0
        self.n_rung_changes = 0
        self.n_watchdog_alarms = 0
        self.recovered: Dict[str, List[_Request]] = {"ready": [],
                                                     "queued": []}
        if not _recovering:
            self._journal({"op": "svc_config",
                           "tenants": [dict(name=t.name, weight=t.weight,
                                            studies=list(t.studies),
                                            deadline=t.deadline)
                                       for t in tenants],
                           "overload": dict(
                               reject_depth=self.overload.reject_depth,
                               degrade_depth=self.overload.degrade_depth,
                               shed_depth=self.overload.shed_depth,
                               p99_slo=self.overload.p99_slo,
                               tenant_queue_cap=(
                                   self.overload.tenant_queue_cap),
                               window=self.overload.window,
                               min_samples=self.overload.min_samples),
                           "quantum": self.quantum,
                           "max_batch": self.max_batch,
                           "max_retries": self.max_retries,
                           "backoff_base": self.backoff_base,
                           "backoff_cap": self.backoff_cap,
                           "backoff_jitter": self.backoff_jitter})

    # ------------------------------------------------------------ plumbing
    def _journal(self, rec: dict) -> None:
        self.fs._append(rec)

    def _now(self) -> float:
        return self.clock.now()

    def p99(self) -> Optional[float]:
        if len(self._lat) < self.overload.min_samples:
            return None
        return float(np.quantile(np.asarray(self._lat), 0.99))

    def queue_depth(self) -> int:
        return (sum(len(t.queue) for t in self._tenants.values())
                + len(self._delayed))

    # ---------------------------------------------------------- submission
    def submit_ask(self, tenant: str, study: Optional[int] = None,
                   deadline: Optional[float] = None) -> _Request:
        """Accept (or refuse) one ask.  Returns the request handle the
        caller polls (``req.done`` / ``req.result`` / ``req.error``).
        Refusals raise: :class:`TenantShedError`, :class:`FleetFullError`
        (overload rung >= reject, or per-tenant backlog cap), or
        :class:`ServiceDraining`."""
        t = self._tenants[tenant]
        if t.shed is not None:
            raise TenantShedError(f"tenant {tenant!r} shed: {t.shed}")
        if self._draining or self._stopped:
            raise ServiceDraining("service is draining")
        if study is None:
            if len(t.cfg.studies) != 1:
                raise ValueError(f"tenant {tenant!r} owns "
                                 f"{len(t.cfg.studies)} studies; pass "
                                 f"study= explicitly")
            study = t.cfg.studies[0]
        if self._study_owner.get(study) != tenant:
            raise ValueError(f"study {study} is not owned by {tenant!r}")
        now = self._now()
        rid = self._req_seq
        cap = self.overload.tenant_queue_cap
        reason = None
        if self._rung >= 1:
            reason = (f"service overloaded (rung "
                      f"{RUNGS[self._rung]}): {self._rung_reason}")
        elif cap is not None and len(t.queue) >= cap:
            reason = (f"tenant {tenant!r} backlog {len(t.queue)} at cap "
                      f"(tenant_queue_cap={cap})")
        if reason is not None:
            self._req_seq += 1
            t.n_rejected += 1
            self.n_rejected += 1
            self._journal({"op": "svc_reject", "req": rid,
                           "tenant": tenant, "reason": reason})
            raise FleetFullError(reason)
        budget = deadline if deadline is not None else t.cfg.deadline
        dl = None if budget is None else now + float(budget)
        # WAL: the accepted request is durable before it is queued
        self._journal({"op": "svc_ask", "req": rid, "tenant": tenant,
                       "study": study, "t": now, "deadline": dl})
        self._req_seq += 1
        req = _Request(rid, tenant, study, now, dl)
        t.queue.append(req)
        t.n_submitted += 1
        return req

    def submit_tell(self, tenant: str, study: int, trial_id: int, y: float,
                    *, failed: bool = False,
                    error: Optional[str] = None) -> None:
        """Validate and apply one tell immediately (tells are O(1) host
        appends; the WAL record is the fleet's own ``tell`` op).  A
        non-finite ``y`` raises before anything is journaled — NaN-tell
        spam never enters the WAL, the GP, or anyone else's schedule."""
        t = self._tenants[tenant]
        if t.shed is not None:
            raise TenantShedError(f"tenant {tenant!r} shed: {t.shed}")
        if self._study_owner.get(study) != tenant:
            raise ValueError(f"study {study} is not owned by {tenant!r}")
        try:
            self.fs.tell(study, trial_id, y, failed=failed, error=error)
        except ValueError:
            t.n_bad_tells += 1
            raise

    # ------------------------------------------------------ the event loop
    def service_step(self) -> int:
        """One scheduling round: watchdog → backoff releases → deadline
        sheds → overload ladder → DRR dispatch → ONE fleet step →
        resolve.  Returns the number of asks that completed."""
        if self._preempt is not None and self._preempt.triggered \
                and not self._draining:
            self.drain()
            return 0
        if self._draining or self._stopped:
            return 0
        now = self._now()
        self._release_delayed(now)
        self._expire_deadlines(now)
        self._update_rung(now)
        with obs.span("svc.drr_round", rung=RUNGS[self._rung]):
            batch = self._drr_schedule(now)
        if not batch:
            return 0
        t0 = now
        with obs.span("svc.dispatch", n=len(batch)):
            served = self._dispatch(batch)
        wall = self._now() - t0
        if (self.watchdog_slow_step is not None
                and wall > self.watchdog_slow_step):
            self.n_watchdog_alarms += 1
            self._journal({"op": "svc_watchdog", "step_wall_s": wall,
                           "batch": [r.rid for r in batch]})
        return served

    def _release_delayed(self, now: float) -> None:
        """Move backoff'd requests whose eligibility time arrived back to
        the head of their tenant queue (rid order preserved)."""
        ready = [r for r in self._delayed if r.not_before <= now]
        if not ready:
            return
        self._delayed = [r for r in self._delayed
                         if r.not_before > now]
        for req in sorted(ready, key=lambda r: -r.rid):
            req.state = "queued"
            self._tenants[req.tenant].queue.appendleft(req)

    def _expire_deadlines(self, now: float) -> None:
        for t in self._tenants.values():
            keep: Deque[_Request] = deque()
            for req in t.queue:
                if req.deadline is not None and now > req.deadline:
                    self._shed_request(req, "deadline exceeded while "
                                       "queued", now)
                else:
                    keep.append(req)
            t.queue = keep
        still = []
        for req in self._delayed:
            if req.deadline is not None and now > req.deadline:
                self._shed_request(req, "deadline exceeded in backoff",
                                   now)
            else:
                still.append(req)
        self._delayed = still

    def _shed_request(self, req: _Request, reason: str,
                      now: float) -> None:
        """WAL, then fail the request; a request that ever dispatched
        also withdraws its fleet-side reservation."""
        self._journal({"op": "svc_shed", "req": req.rid,
                       "kind": "deadline", "reason": reason})
        obs.instant("svc.shed", req=req.rid, tenant=req.tenant,
                    kind="deadline", reason=reason)
        if req.attempts > 0 or req.state == "dispatched":
            self.fs.cancel_ask(req.study)
        req.state = "shed"
        req.error = DeadlineExceeded(
            f"request {req.rid} ({req.tenant!r}/study {req.study}): "
            f"{reason}")
        req.done_t = now
        t = self._tenants[req.tenant]
        t.n_shed += 1
        t.n_deadline_miss += 1
        self.n_shed += 1
        self.n_deadline_miss += 1
        req._wake()

    # ------------------------------------------------------ overload ladder
    def _update_rung(self, now: float) -> None:
        oc = self.overload
        depth = self.queue_depth()
        # The p99 estimate only refreshes on completions.  With an empty
        # queue there are no completions coming (rung >= 1 refuses new
        # asks), so a stale over-SLO window would otherwise freeze the
        # service in reject forever; p99 rungs apply only while a
        # backlog exists to refresh the estimate.
        p99 = self.p99() if depth > 0 else None
        rung, why = 0, ""
        checks = [(1, oc.reject_depth, 1.0), (2, oc.degrade_depth, 2.0),
                  (3, oc.shed_depth, 4.0)]
        for level, dth, slo_mult in checks:
            if depth >= dth:
                rung, why = level, f"queue depth {depth} >= {dth}"
            elif (oc.p99_slo is not None and p99 is not None
                    and p99 >= slo_mult * oc.p99_slo):
                rung, why = level, (f"p99 {p99:.3f}s >= "
                                    f"{slo_mult:g}x SLO {oc.p99_slo}s")
        if rung == self._rung:
            return
        prev = self._rung
        self._journal({"op": "svc_overload", "rung": RUNGS[rung],
                       "from": RUNGS[prev], "depth": depth, "p99": p99,
                       "reason": why})
        obs.instant("svc.rung_change", rung=RUNGS[rung],
                    from_rung=RUNGS[prev], depth=depth, reason=why)
        self._rung, self._rung_reason = rung, why
        self.n_rung_changes += 1
        if rung >= 2 and prev < 2:
            self._degrade_lowest_weight(why)
        if rung >= 3 and prev < 3:
            self._shed_lowest_weight(why, now)

    def _victim(self, *, skip_degraded: bool) -> Optional[_TenantState]:
        cands = [t for t in self._tenants.values() if t.shed is None
                 and not (skip_degraded and t.degraded is not None)]
        if len(cands) <= 1:
            return None              # never degrade/shed the only tenant
        return min(cands, key=lambda t: (t.cfg.weight, t.cfg.name))

    def _degrade_lowest_weight(self, why: str) -> None:
        """Ladder rung 2: move the lowest-weight tenant's studies off the
        shared fleet plane onto the solo AskEngine path — capacity for
        everyone else, continued (slower) service for the victim."""
        t = self._victim(skip_degraded=True)
        if t is None:
            return
        reason = f"service overload degrade: {why}"
        self._journal({"op": "svc_degrade", "tenant": t.cfg.name,
                       "studies": list(t.cfg.studies), "reason": reason})
        obs.instant("svc.degrade", tenant=t.cfg.name, reason=reason)
        t.degraded = reason
        for study in t.cfg.studies:
            s = self.fs.samplers[study]
            if s._fleet is not None:
                sid = s._fleet_sid
                self.fs.fleet.shed_study(sid, reason)
                s._detach_fleet(reason)

    def _shed_lowest_weight(self, why: str, now: float) -> None:
        """Ladder rung 3: drop the lowest-weight tenant entirely."""
        t = self._victim(skip_degraded=False)
        if t is None:
            return
        reason = f"service overload shed: {why}"
        dropped = [r.rid for r in t.queue] + \
                  [r.rid for r in self._delayed if r.tenant == t.cfg.name]
        self._journal({"op": "svc_shed_tenant", "tenant": t.cfg.name,
                       "reason": reason, "dropped": dropped})
        obs.instant("svc.shed_tenant", tenant=t.cfg.name,
                    n_dropped=len(dropped), reason=reason)
        t.shed = reason
        mine = list(t.queue) + [r for r in self._delayed
                                if r.tenant == t.cfg.name]
        t.queue.clear()
        self._delayed = [r for r in self._delayed
                         if r.tenant != t.cfg.name]
        for req in mine:         # queued AND backoff-delayed both resolve
            req.state = "shed"
            req.error = TenantShedError(reason)
            req.done_t = now
            t.n_shed += 1
            self.n_shed += 1
            req._wake()
        for study in t.cfg.studies:
            s = self.fs.samplers[study]
            if s._fleet is not None:
                sid = s._fleet_sid
                self.fs.fleet.shed_study(sid, reason)
                s._detach_fleet(reason)

    # --------------------------------------------------------- scheduling
    def _drr_schedule(self, now: float) -> List[_Request]:
        """Deficit round robin over tenant queues: refill each active
        tenant's deficit by quantum x weight, serve head requests at unit
        cost while it lasts.  At most one in-flight ask per study per
        round (a study's suggest is a single slot reservation)."""
        batch: List[_Request] = []
        seen_studies = set()
        for name in self._order:
            t = self._tenants[name]
            if t.shed is not None or not t.queue:
                continue
            t.deficit += self.quantum * t.cfg.weight
            while t.queue and t.deficit >= 1.0:
                if self.max_batch is not None \
                        and len(batch) >= self.max_batch:
                    break
                head = t.queue[0]
                if head.study in seen_studies:
                    break            # one reservation per study per round
                t.queue.popleft()
                t.deficit -= 1.0
                head.state = "dispatched"
                batch.append(head)
                seen_studies.add(head.study)
            if not t.queue:
                t.deficit = 0.0      # classic DRR: empty queue resets
        return batch

    def _dispatch(self, batch: List[_Request]) -> int:
        """Journal dispatches, run ONE batched fleet trial boundary for
        the scheduled studies, resolve results/retries/late sheds."""
        fi = self.fs.fault_injector
        live: List[_Request] = []
        for req in batch:
            self._journal({"op": "svc_dispatch", "req": req.rid,
                           "study": req.study})
            req.attempts += 1
            if fi is not None and hasattr(fi, "ask_ok") \
                    and not fi.ask_ok(req.study):
                self._retry(req, RuntimeError(
                    f"injected transient dispatch failure "
                    f"(study {req.study})"))
                continue
            live.append(req)
        if not live:
            return 0
        trials = self.fs.ask_batch([r.study for r in live])
        t1 = self._now()
        served = 0
        for req, trial in zip(live, trials):
            if isinstance(trial, Exception):
                self._retry(req, trial)
                continue
            if req.deadline is not None and t1 > req.deadline:
                # came back late: cancel-and-shed (the pending trial is
                # simply never told; recovery lists it as re-evaluable)
                self._shed_request(req, "deadline exceeded in flight", t1)
                continue
            self._journal({"op": "svc_done", "req": req.rid,
                           "trial": trial.trial_id})
            req.result = trial
            req.state = "done"
            req.done_t = t1
            lat = t1 - req.submit_t
            self._lat.append(lat)
            t = self._tenants[req.tenant]
            t.n_served += 1
            t.latencies.append(lat)
            self.n_completed += 1
            served += 1
            req._wake()
        return served

    def _retry(self, req: _Request, err: BaseException) -> None:
        """Transient failure: bounded exponential backoff with jitter,
        then back into the tenant queue; exhaustion fails the request."""
        t = self._tenants[req.tenant]
        if req.attempts > self.max_retries:
            self._journal({"op": "svc_shed", "req": req.rid,
                           "kind": "failed",
                           "reason": f"retries exhausted: {err}"})
            obs.instant("svc.shed", req=req.rid, tenant=req.tenant,
                        kind="failed")
            req.state = "failed"
            req.error = RequestFailed(
                f"request {req.rid}: {req.attempts} attempts failed; "
                f"last: {err}")
            req.done_t = self._now()
            t.n_shed += 1
            self.n_shed += 1
            req._wake()
            return
        delay = min(self.backoff_base * (2.0 ** (req.attempts - 1)),
                    self.backoff_cap)
        delay *= 1.0 + self.backoff_jitter * float(
            self._backoff_rng.random())
        not_before = self._now() + delay
        self._journal({"op": "svc_retry", "req": req.rid,
                       "attempt": req.attempts, "delay_s": delay,
                       "not_before": not_before, "error": str(err)})
        obs.instant("svc.retry", req=req.rid, tenant=req.tenant,
                    attempt=req.attempts, delay_s=delay)
        req.not_before = not_before
        req.state = "delayed"
        self._delayed.append(req)
        t.n_retries += 1
        self.n_retries += 1

    # ------------------------------------------------------ watchdog/drain
    def install_watchdog(self):
        """Arm SIGTERM/SIGUSR1 → drain-at-request-boundary (the PR-7
        preemption flag); returns the flag for external pollers."""
        self._preempt = self.fs.install_drain_handler()
        return self._preempt

    def drain(self) -> dict:
        """Graceful shutdown: journal the pending queue (it survives to
        recovery — in-flight requests are journaled before any state
        changes), fail outstanding futures with ServiceDraining, then
        checkpoint + close through :meth:`FleetSampler.drain`."""
        queued = [r.rid for t in self._tenants.values() for r in t.queue]
        queued += [r.rid for r in self._delayed]
        self._journal({"op": "svc_drain", "queued": sorted(queued)})
        obs.instant("svc.drain", n_queued=len(queued))
        self._draining = True
        now = self._now()
        for t in self._tenants.values():
            for req in t.queue:
                req.state = "shed"
                req.error = ServiceDraining(
                    f"request {req.rid} interrupted by drain (journaled; "
                    f"recovery restores it)")
                req.done_t = now
                req._wake()
            t.queue.clear()
        for req in self._delayed:
            req.state = "shed"
            req.error = ServiceDraining(
                f"request {req.rid} interrupted by drain (journaled; "
                f"recovery restores it)")
            req.done_t = now
            req._wake()
        self._delayed = []
        return self.fs.drain()

    def stop(self) -> None:
        self._stopped = True

    # ------------------------------------------------------------ recovery
    @classmethod
    def recover(cls, journal_dir: str, *, mesh=None, fault_injector=None,
                clock=None) -> Tuple["BOService", "object"]:
        """Rebuild a crashed/drained service from its journal directory.

        Fleet state recovers through :meth:`FleetSampler.recover` (the
        normal paths — bitwise at ``refit_interval=1``).  The service
        ledger then replays the ``svc_*`` records: every accepted ask
        that never resolved is restored — never-dispatched (or
        dispatched-but-never-asked) requests re-enter their tenant
        queues in rid order and recompute the identical suggestion
        (same key, same observations); requests whose ask WAS journaled
        but never delivered come back pre-resolved in
        ``service.recovered["ready"]`` for the driver to collect.
        Returns ``(service, RecoveryReport)``."""
        sleep_fn = None if clock is None else clock.sleep
        fs, rep = FleetSampler.recover(journal_dir, mesh=mesh,
                                       fault_injector=fault_injector,
                                       sleep_fn=sleep_fn)
        records = fs.journal.replay()
        svc_cfg = next((r for r in records if r.get("op") == "svc_config"),
                       None)
        if svc_cfg is None:
            raise ValueError(f"journal at {journal_dir!r} has no "
                             f"svc_config record — not a BOService "
                             f"journal")
        tenants = [TenantConfig(name=t["name"], weight=t["weight"],
                                studies=tuple(t["studies"]),
                                deadline=t["deadline"])
                   for t in svc_cfg["tenants"]]
        svc = cls(fs, tenants, overload=OverloadConfig(**svc_cfg[
                      "overload"]),
                  quantum=svc_cfg["quantum"],
                  max_batch=svc_cfg["max_batch"],
                  max_retries=svc_cfg["max_retries"],
                  backoff_base=svc_cfg["backoff_base"],
                  backoff_cap=svc_cfg["backoff_cap"],
                  backoff_jitter=svc_cfg["backoff_jitter"],
                  clock=clock, _recovering=True)
        # ---- replay the request ledger
        ledger: Dict[int, _Request] = {}
        dispatched: Dict[int, int] = {}   # study -> rid awaiting its ask
        max_rid = -1
        for rec in records:
            op = rec.get("op")
            if op == "svc_ask":
                rid = rec["req"]
                max_rid = max(max_rid, rid)
                ledger[rid] = _Request(rid, rec["tenant"], rec["study"],
                                       rec["t"], rec["deadline"])
            elif op == "svc_reject":
                max_rid = max(max_rid, rec["req"])
            elif op == "svc_dispatch":
                req = ledger.get(rec["req"])
                if req is not None and not req.done:
                    req.attempts += 1
                    dispatched[req.study] = req.rid
            elif op == "ask":
                rid = dispatched.pop(rec["study"], None)
                if rid is not None and not ledger[rid].done:
                    # the suggest was journaled: deliver it on restart
                    ledger[rid].result = fs.samplers[
                        rec["study"]].trials[rec["trial"]]
                    ledger[rid].state = "done"
            elif op == "svc_done":
                req = ledger.get(rec["req"])
                if req is not None:
                    req.state = "done"
                    req.done_t = -1.0        # delivered before the crash
                    req.result = fs.samplers[req.study].trials[
                        rec["trial"]]
                    dispatched.pop(req.study, None)
            elif op == "svc_retry":
                req = ledger.get(rec["req"])
                if req is not None:
                    req.state = "queued"     # backoff restarts fresh
                    dispatched.pop(req.study, None)
            elif op == "svc_shed":
                req = ledger.get(rec["req"])
                if req is not None:
                    # two shed kinds share the record: deadline sheds
                    # and retries-exhausted failures keep their live
                    # error class through replay (older journals lack
                    # the field — fall back on the reason text)
                    kind = rec.get("kind")
                    if kind is None:
                        kind = ("failed" if rec["reason"].startswith(
                            "retries exhausted") else "deadline")
                    if kind == "failed":
                        req.state = "failed"
                        req.error = RequestFailed(rec["reason"])
                    else:
                        req.state = "shed"
                        req.error = DeadlineExceeded(rec["reason"])
                    dispatched.pop(req.study, None)
            elif op == "svc_overload":
                svc._rung = RUNGS.index(rec["rung"])
                svc._rung_reason = rec.get("reason", "")
            elif op == "svc_degrade":
                t = svc._tenants.get(rec["tenant"])
                if t is not None:
                    t.degraded = rec["reason"]
            elif op == "svc_shed_tenant":
                t = svc._tenants.get(rec["tenant"])
                if t is not None:
                    t.shed = rec["reason"]
                for rid in rec.get("dropped", ()):
                    if rid in ledger:
                        ledger[rid].state = "shed"
                        ledger[rid].error = TenantShedError(rec["reason"])
            # svc_drain / svc_watchdog / fleet ops: informational here
        svc._req_seq = max_rid + 1
        # ---- restore the pending queue (rid order == submission order)
        for rid in sorted(ledger):
            req = ledger[rid]
            t = svc._tenants[req.tenant]
            if req.state == "done" and req.done_t is None:
                # asked-but-undelivered: ready result for the driver
                svc.recovered["ready"].append(req)
            elif not req.done and t.shed is None:
                req.state = "queued"
                req.attempts = 0
                t.queue.append(req)
                svc.recovered["queued"].append(req)
        return svc, rep

    # ---------------------------------------------------------- observers
    def stats_snapshot(self) -> dict:
        snap = self.fs.stats_snapshot()
        p99 = self.p99()
        snap.update({
            "svc_rung": RUNGS[self._rung],
            "svc_queue_depth": self.queue_depth(),
            "svc_completed": self.n_completed,
            "svc_shed": self.n_shed,
            "svc_deadline_miss": self.n_deadline_miss,
            "svc_rejected": self.n_rejected,
            "svc_retries": self.n_retries,
            "svc_rung_changes": self.n_rung_changes,
            "svc_watchdog_alarms": self.n_watchdog_alarms,
            "svc_p99_s": p99,
            "svc_tenants": {
                name: dict(weight=t.cfg.weight,
                           queue=len(t.queue),
                           submitted=t.n_submitted, served=t.n_served,
                           shed=t.n_shed,
                           deadline_miss=t.n_deadline_miss,
                           rejected=t.n_rejected,
                           bad_tells=t.n_bad_tells, retries=t.n_retries,
                           degraded=t.degraded is not None,
                           is_shed=t.shed is not None)
                for name, t in self._tenants.items()},
        })
        return snap

    def tenant_latencies(self, tenant: str) -> List[float]:
        return list(self._tenants[tenant].latencies)

    # -------------------------------------------------------- async facade
    async def ask(self, tenant: str, study: Optional[int] = None,
                  deadline: Optional[float] = None) -> Trial:
        req = self.submit_ask(tenant, study, deadline)
        if not req.done:
            # event-wait, not a sleep(0) poll loop: the waiting client
            # coroutine parks until the server task resolves the
            # request (every terminal transition calls req._wake()),
            # so idle waiters cost the event loop nothing
            req.event = asyncio.Event()
            if req.done:     # resolved between submit and attach
                req.event.set()
            await req.event.wait()
        if req.error is not None:
            raise req.error
        return req.result

    async def tell(self, tenant: str, study: int, trial_id: int, y: float,
                   *, failed: bool = False,
                   error: Optional[str] = None) -> None:
        self.submit_tell(tenant, study, trial_id, y, failed=failed,
                         error=error)
        await asyncio.sleep(0)

    async def run(self, *, idle_sleep: float = 0.001) -> None:
        """The server task: drive the loop until :meth:`stop` or drain.
        Runs the (synchronous) fleet step inline — single-threaded by
        design — and yields to client coroutines between rounds."""
        while not self._stopped and not self._draining:
            n = self.service_step()
            await asyncio.sleep(0 if n else idle_sleep)
