"""Core of the invariant lint engine: findings, rules, project model.

The linter enforces the ROADMAP contracts *statically*: every rule is a
pure function over parsed ASTs, so a violating call site is caught at
review time even when no runtime test exercises it.  The model is
deliberately small:

* :class:`Finding` — one violation (rule id, file:line, severity,
  message, enclosing function, source snippet).
* :class:`Rule` — a named check run once per module with the whole
  :class:`Project` available for cross-module facts.
* :class:`ModuleInfo` — one parsed file plus its inline suppressions.
* :class:`Project` — all modules, a bare-name function table, and the
  *traced closure*: the set of functions reachable from any function
  handed to ``CountingJit`` / ``jax.jit`` / ``shard_map`` / ``vmap`` /
  ``lax.while_loop``-family combinators.  Trace-discipline rules
  (host-leak, nan-hazard) scope themselves to that closure.

Name resolution is heuristic by design (bare last-segment matching,
same-module candidates preferred).  False positives are expected to be
*triaged*, not silenced: either fix the code, or suppress with a reason
(inline ``# repro: allow[rule-id] reason`` or a baseline entry — both
reject empty reasons).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SEV_ERROR = "error"
SEV_WARNING = "warning"

# inline suppression: ``# repro: allow[rule-id] reason text``
_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([a-z0-9-]+)\]\s*(.*)$")


@dataclasses.dataclass
class Finding:
    rule: str
    file: str                 # repo-relative path
    line: int
    severity: str
    message: str
    func: str = ""            # enclosing function qualname ("" = module)
    snippet: str = ""         # stripped source line (baseline matching)

    def key(self) -> Tuple[str, str, str, str]:
        """Line-number-free identity used for baseline matching."""
        return (self.rule, self.file, self.func, self.snippet)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        where = f" (in {self.func})" if self.func else ""
        return (f"{self.file}:{self.line}: [{self.rule}] "
                f"{self.severity}: {self.message}{where}")


class Rule:
    """Base class: subclasses set ``id``/``severity`` and implement
    :meth:`run`."""
    id: str = ""
    severity: str = SEV_ERROR
    doc: str = ""

    def run(self, module: "ModuleInfo", project: "Project") -> List[Finding]:
        raise NotImplementedError


# --------------------------------------------------------------------------
# AST helpers
# --------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_segment(node: ast.AST) -> Optional[str]:
    """Final attribute/name of a call target: ``self.x.append`` → append."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def call_target(call: ast.Call) -> Optional[str]:
    return last_segment(call.func)


def const_int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Evaluate a literal tuple/list of ints (``donate_argnums=(5, 6)``)."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return None


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


class _Parented(ast.NodeVisitor):
    """Annotate every node with ``._parent`` (rules walk upward for
    context, e.g. "is this inf literal inside a jnp.where call?")."""

    def generic_visit(self, node):
        for child in ast.iter_child_nodes(node):
            child._parent = node          # type: ignore[attr-defined]
        super().generic_visit(node)


def parent_of(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_parent", None)


def ancestors(node: ast.AST) -> Iterable[ast.AST]:
    cur = parent_of(node)
    while cur is not None:
        yield cur
        cur = parent_of(cur)


# --------------------------------------------------------------------------
# module / project model
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FuncInfo:
    name: str                       # bare name ("" for lambdas)
    qualname: str                   # Class.method / outer.<locals>.inner
    module: "ModuleInfo"
    node: ast.AST                   # FunctionDef | AsyncFunctionDef | Lambda
    is_method: bool = False         # first param is self/cls
    static_params: Set[str] = dataclasses.field(default_factory=set)

    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        return names


class ModuleInfo:
    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        _Parented().visit(self.tree)
        # line → (rule-id, reason) inline suppressions
        self.allows: Dict[int, Tuple[str, str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(text)
            if m:
                self.allows[i] = (m.group(1), m.group(2).strip())

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: Rule, node: ast.AST, message: str,
                func: str = "", severity: Optional[str] = None) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule.id, file=self.rel, line=line,
                       severity=severity or rule.severity, message=message,
                       func=func, snippet=self.line_text(line))

    def allow_for(self, finding: Finding) -> Optional[Tuple[str, str]]:
        """Inline allow covering this finding (same or previous line)."""
        for ln in (finding.line, finding.line - 1):
            ent = self.allows.get(ln)
            if ent and ent[0] == finding.rule:
                return ent
        return None


# combinators whose first argument becomes traced code
_TRACE_WRAPPERS_ARG0 = {
    "CountingJit", "jit", "vmap", "pmap", "grad", "value_and_grad",
    "shard_map", "pallas_call", "checkpoint", "custom_jvp", "custom_vjp",
    "scan",
}
# (name → indices of function-valued args)
_TRACE_WRAPPERS_MULTI = {
    "while_loop": (0, 1),
    "cond": (1, 2),
    "fori_loop": (2,),
}


class Project:
    """All parsed modules plus cross-module derived facts."""

    def __init__(self, modules: Sequence[ModuleInfo]):
        self.modules = list(modules)
        # bare function name → candidates (module-order stable)
        self.functions: Dict[str, List[FuncInfo]] = {}
        self._func_by_node: Dict[int, FuncInfo] = {}
        for mod in self.modules:
            self._index_functions(mod)
        # traced closure (all jit-family roots) and the while_loop-carry
        # closure (nan rule scope)
        self.traced: Set[int] = set()           # id(node) of FuncInfo.node
        self.while_closure: Set[int] = set()
        self._build_traced_closure()

    # -------------------------------------------------- function table
    def _index_functions(self, mod: ModuleInfo) -> None:
        def visit(node: ast.AST, qual: str, in_class: bool):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{qual}.{child.name}" if qual else child.name
                    args = child.args
                    names = [p.arg for p in args.posonlyargs + args.args]
                    fi = FuncInfo(name=child.name, qualname=q, module=mod,
                                  node=child,
                                  is_method=in_class and bool(names)
                                  and names[0] in ("self", "cls"))
                    self.functions.setdefault(child.name, []).append(fi)
                    self._func_by_node[id(child)] = fi
                    visit(child, q, in_class=False)
                elif isinstance(child, ast.ClassDef):
                    q = f"{qual}.{child.name}" if qual else child.name
                    visit(child, q, in_class=True)
                else:
                    visit(child, qual, in_class)
        visit(mod.tree, "", False)

    def func_for_node(self, node: ast.AST) -> Optional[FuncInfo]:
        return self._func_by_node.get(id(node))

    def enclosing_function(self, node: ast.AST) -> str:
        for anc in ancestors(node):
            fi = self._func_by_node.get(id(anc))
            if fi is not None:
                return fi.qualname
        return ""

    def resolve(self, expr: ast.AST, mod: ModuleInfo,
                encl: Optional[ast.AST] = None,
                depth: int = 0) -> List[FuncInfo]:
        """Resolve a function-valued expression to candidate defs.

        Resolution is deliberately conservative — over-resolving a
        common name (``step``, ``append``) would taint whole host
        subsystems into the traced closure:

        * bare names: the *enclosing function's* locals first (nested
          defs, ``f = partial(g, ...)``-style rebindings), then
          module-level defs, then a global match only when the name is
          unique project-wide;
        * ``self.X``: same-module definitions only;
        * other dotted attributes: same module, else unique-global;
        * ``functools.partial(f, ...)`` unwraps to ``f``; inline lambdas
          resolve to themselves.
        """
        if depth > 4:
            return []
        if isinstance(expr, ast.Lambda):
            fi = self._func_by_node.get(id(expr))
            if fi is None:
                fi = FuncInfo(name="", qualname="<lambda>", module=mod,
                              node=expr)
                self._func_by_node[id(expr)] = fi
            return [fi]
        if isinstance(expr, ast.Call) and call_target(expr) == "partial":
            return self.resolve(expr.args[0], mod, encl, depth + 1) \
                if expr.args else []
        if isinstance(expr, ast.Name):
            if encl is not None:
                hit = self._resolve_local(expr.id, encl, mod, depth)
                if hit is not None:
                    return hit
            cands = self.functions.get(expr.id, [])
            local = [c for c in cands if c.module is mod]
            if local:
                return local
            return cands if len(cands) == 1 else []
        if isinstance(expr, ast.Attribute):
            chain = dotted_name(expr)
            cands = self.functions.get(expr.attr, [])
            local = [c for c in cands if c.module is mod]
            if chain is not None and chain.startswith(("self.", "cls.")) \
                    and chain.count(".") == 1:
                return local
            if local:
                return local
            return cands if len(cands) == 1 else []
        return []

    def _resolve_local(self, name: str, encl: ast.AST, mod: ModuleInfo,
                      depth: int) -> Optional[List[FuncInfo]]:
        """Locals of ``encl`` shadow the tables: a nested def wins, and a
        ``name = <expr>`` assignment resolves through its value.  Returns
        None when ``name`` is not bound locally."""
        for node in ast.walk(encl):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not encl and node.name == name:
                fi = self._func_by_node.get(id(node))
                return [fi] if fi else []
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == name:
                        return self.resolve(node.value, mod, encl,
                                            depth + 1)
        # a parameter of the enclosing function: opaque, don't guess
        args = getattr(encl, "args", None)
        if args is not None:
            params = {p.arg for p in args.posonlyargs + args.args
                      + args.kwonlyargs}
            if name in params:
                return []
        return None

    # -------------------------------------------------- traced closure
    def _trace_roots(self) -> List[Tuple[FuncInfo, ast.Call, int]]:
        """(func, wrapping call, arg position) for every combinator use."""
        roots = []
        for mod in self.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # decorator forms: @jax.jit / @jit /
                    # @functools.partial(jax.jit, static_argnums=...)
                    for dec in node.decorator_list:
                        call = None
                        if isinstance(dec, ast.Call) \
                                and call_target(dec) == "partial" \
                                and dec.args \
                                and last_segment(dec.args[0]) in \
                                _TRACE_WRAPPERS_ARG0:
                            call = dec
                            wrapper = last_segment(dec.args[0])
                        elif last_segment(dec) in _TRACE_WRAPPERS_ARG0:
                            wrapper = last_segment(dec)
                            call = ast.Call(func=dec, args=[], keywords=[])
                        elif isinstance(dec, ast.Call) \
                                and call_target(dec) in _TRACE_WRAPPERS_ARG0:
                            wrapper = call_target(dec)
                            call = dec
                        else:
                            continue
                        fi = self._func_by_node.get(id(node))
                        if fi is not None:
                            call._trace_wrapper = wrapper  # type: ignore
                            roots.append((fi, call, 0))
                    continue
                if not isinstance(node, ast.Call):
                    continue
                tgt = call_target(node)
                if tgt in _TRACE_WRAPPERS_ARG0:
                    idxs: Tuple[int, ...] = (0,)
                elif tgt in _TRACE_WRAPPERS_MULTI:
                    idxs = _TRACE_WRAPPERS_MULTI[tgt]
                else:
                    continue
                encl = self._enclosing_funcdef(node)
                for i in idxs:
                    if i < len(node.args):
                        for fi in self.resolve(node.args[i], mod, encl):
                            roots.append((fi, node, i))
        return roots

    def _enclosing_funcdef(self, node: ast.AST) -> Optional[ast.AST]:
        for anc in ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def _attach_static_params(self, fi: FuncInfo, call: ast.Call) -> None:
        """Record which params of a jit root are static (their values are
        legal subjects for Python control flow inside the trace)."""
        kw = keyword_arg(call, "static_argnums")
        nums = const_int_tuple(kw) if kw is not None else None
        if not nums:
            return
        params = fi.params()
        # a bound-method root (CountingJit(self._impl)) drops ``self`` at
        # call time, so static position i names param i+1
        off = 1 if (params and params[0] in ("self", "cls")) else 0
        for n in nums:
            if 0 <= n + off < len(params):
                fi.static_params.add(params[n + off])

    def _expand(self, seed: Iterable[FuncInfo]) -> Set[int]:
        """Transitive closure over calls from ``seed``.

        Follows bare-name calls (resolved against the caller's locals
        first), ``self.X`` method calls (same module), and
        function-valued arguments handed to ``*_jit`` program objects /
        ``partial``.  Everything else — ``obj.method(...)`` on arbitrary
        receivers — is opaque: following those by bare last-segment name
        would drag host subsystems into the traced set via names like
        ``append`` or ``step``."""
        seen: Set[int] = set()
        frontier = list(seed)
        while frontier:
            fi = frontier.pop()
            if id(fi.node) in seen:
                continue
            seen.add(id(fi.node))
            encl = fi.node
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                tgt = call_target(node)
                exprs: List[ast.AST] = []
                fn = node.func
                if isinstance(fn, ast.Name):
                    exprs.append(fn)
                elif isinstance(fn, ast.Attribute):
                    chain = dotted_name(fn)
                    if chain is not None and chain.startswith(
                            ("self.", "cls.")) and chain.count(".") == 1:
                        exprs.append(fn)
                if tgt == "partial" and node.args:
                    exprs.append(node.args[0])
                if tgt is not None and (tgt.endswith("_jit")
                                        or tgt == "jitted"):
                    # calls *through* a jit program object: its function-
                    # valued args (batched objectives) are traced too
                    exprs.extend(a for a in node.args
                                 if isinstance(a, (ast.Name, ast.Lambda)))
                for expr in exprs:
                    for cand in self.resolve(expr, fi.module, encl):
                        if id(cand.node) not in seen:
                            frontier.append(cand)
        return seen

    def _build_traced_closure(self) -> None:
        roots = self._trace_roots()
        all_seed, while_seed = [], []
        for fi, call, pos in roots:
            all_seed.append(fi)
            tgt = getattr(call, "_trace_wrapper", None) or call_target(call)
            if tgt in ("CountingJit", "jit"):
                self._attach_static_params(fi, call)
            if tgt in ("while_loop", "scan", "fori_loop"):
                while_seed.append(fi)
        self.traced = self._expand(all_seed)
        self.while_closure = self._expand(while_seed)

    def is_traced(self, funcdef: ast.AST) -> bool:
        return id(funcdef) in self.traced

    def in_while_closure(self, funcdef: ast.AST) -> bool:
        return id(funcdef) in self.while_closure


def load_project(paths: Sequence[Path], root: Path,
                 exclude: Sequence[str] = ("tests",)) -> Project:
    files: List[Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
    mods = []
    for f in files:
        rel = str(f.resolve().relative_to(root.resolve())) \
            if f.resolve().is_relative_to(root.resolve()) else str(f)
        if any(part in exclude for part in Path(rel).parts):
            continue
        try:
            src = f.read_text()
            mods.append(ModuleInfo(f, rel, src))
        except (SyntaxError, UnicodeDecodeError):
            continue
    return Project(mods)
