"""Distributed tests.  Mesh-requiring cases run in SUBPROCESSES (via the
shared ``run_sub`` conftest fixture) so the host-device-count flag never
leaks into the rest of the suite (per the dry-run isolation requirement)."""
import jax

from repro.distributed.sharding import pspec


# ------------------------------------------------------------------ pspec
def test_pspec_greedy_rules():
    names = ("pod", "data", "model")
    sizes = {"pod": 2, "data": 16, "model": 16}
    assert pspec((256, 4096), ("batch", None), names, sizes) \
        == jax.sharding.PartitionSpec(("pod", "data"), None)
    # kv_heads=8 indivisible by model=16 → falls through; head takes it
    assert pspec((128, 32768, 8, 128),
                 ("batch_full", "kv_seq", "kv_heads", "head"),
                 names, sizes)[2] is None
    assert pspec((128, 32768, 8, 128),
                 ("batch_full", "kv_seq", "kv_heads", "head"),
                 names, sizes)[3] == "model"
    # each mesh axis used at most once per tensor
    sp = pspec((64, 64), ("vocab", "ff"), names, sizes)
    assert sp == jax.sharding.PartitionSpec("model", None)


def test_pspec_single_device_mesh_noop():
    assert pspec((8, 8), ("batch", "vocab"), ("data", "model"),
                 {"data": 1, "model": 1}) \
        == jax.sharding.PartitionSpec(None, None)


# -------------------------------------------------------------- lowering
def test_train_step_lowers_on_smoke_mesh(run_sub):
    out = run_sub("""
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.launch.shapes import ShapeCell, build_cell
        cfg = get_config("llama3.2-3b").reduced().replace(
            dtype="float32", attn_chunk=16)
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        cell = ShapeCell("mini_train", "train", 32, 8)
        with use_mesh(mesh):
            step, args, shards, outs, donate = build_cell(
                cfg, cell, mesh, grad_accum=2)
            c = jax.jit(step, in_shardings=shards, out_shardings=outs,
                        donate_argnums=donate).lower(*args).compile()
        print("COMPILED", c.memory_analysis().temp_size_in_bytes)
    """)
    assert "COMPILED" in out


def test_decode_lowers_on_smoke_mesh(run_sub):
    out = run_sub("""
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.launch.shapes import ShapeCell, build_cell
        cfg = get_config("recurrentgemma-9b").reduced().replace(
            dtype="float32", attn_chunk=16)
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        cell = ShapeCell("mini_decode", "decode", 64, 8)
        with use_mesh(mesh):
            step, args, shards, outs, donate = build_cell(cfg, cell, mesh)
            c = jax.jit(step, in_shardings=shards, out_shardings=outs,
                        donate_argnums=donate).lower(*args).compile()
        print("COMPILED")
    """)
    assert "COMPILED" in out


def test_moe_sharded_matches_unsharded(run_sub):
    """EP shard_map output == single-device reference (same params/input)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import apply_moe, init_moe
        cfg = get_config("dbrx-132b").reduced().replace(
            dtype="float32", moe_capacity_factor=100.0)
        key = jax.random.PRNGKey(0)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        y_ref, aux_ref = apply_moe(p, cfg, x)        # no mesh: local path
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            y_sh, aux_sh = jax.jit(lambda p, x: apply_moe(p, cfg, x))(p, x)
        err = float(jnp.max(jnp.abs(y_ref - y_sh)))
        print("ERR", err, float(aux_ref), float(aux_sh))
        assert err < 1e-4, err
    """)
    assert "ERR" in out


def test_sharded_ce_matches_unsharded(run_sub):
    """Vocab-sharded cross-entropy == plain CE."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("llama3.2-3b").reduced().replace(
            dtype="float32", attn_chunk=16)
        key = jax.random.PRNGKey(0)
        params = lm.init_params(key, cfg)
        toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        tgts = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "targets": tgts}
        ref = float(jax.jit(lambda p, b: lm.lm_loss(p, cfg, b))(params,
                                                                batch))
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            sh = float(jax.jit(lambda p, b: lm.lm_loss(p, cfg, b))(params,
                                                                   batch))
        print("LOSSES", ref, sh)
        assert abs(ref - sh) < 1e-4
    """)
    assert "LOSSES" in out


def test_elastic_restore_across_meshes(run_sub):
    """Checkpoint on a (2,4) mesh, restore on (4,2) — values identical."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.manager import CheckpointManager
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        m1 = make_smoke_mesh((2, 4), ("data", "model"))
        m2 = make_smoke_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        x1 = jax.device_put(x, NamedSharding(m1, P("data", "model")))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, {"x": x1}, block=True)
            restored = mgr.restore(
                1, {"x": x},
                shardings={"x": NamedSharding(m2, P("model", "data"))})
            np.testing.assert_array_equal(np.asarray(restored["x"]),
                                          np.asarray(x))
            print("ELASTIC_OK", restored["x"].sharding.spec)
    """)
    assert "ELASTIC_OK" in out


def test_grad_compression_bf16_shrinks_accumulator(run_sub):
    """bf16 grad accumulation halves the gradient-accumulator footprint.

    Verified structurally on the compiled HLO: with compression the scan
    carry / collectives materialize bf16 buffers, without it (f32 model)
    the program contains none.  (Total temp bytes are NOT asserted — at
    smoke scale XLA's cast scratch outweighs the accumulator saving and
    the accounting shifts between backend versions.)"""
    out = run_sub("""
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.launch.shapes import ShapeCell, build_cell
        from repro.train.optim import OptimConfig
        cfg = get_config("llama3.2-3b").reduced().replace(
            dtype="float32", attn_chunk=16)
        mesh = make_smoke_mesh((4, 2), ("data", "model"))
        cell = ShapeCell("mini_train", "train", 32, 8)
        nbf16 = {}
        for mode in ("none", "bf16"):
            oc = OptimConfig(grad_compression=mode, shard_grads=False)
            with use_mesh(mesh):
                step, args, shards, outs, donate = build_cell(
                    cfg, cell, mesh, opt_cfg=oc, grad_accum=4)
                comp = jax.jit(step, in_shardings=shards,
                               out_shardings=outs,
                               donate_argnums=donate).lower(*args).compile()
            nbf16[mode] = comp.as_text().count("bf16[")
        print("BF16_BUFS", nbf16["none"], nbf16["bf16"])
        assert nbf16["none"] == 0, nbf16
        assert nbf16["bf16"] > 0, nbf16
    """)
    assert "BF16_BUFS" in out
