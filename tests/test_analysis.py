"""Tests for the invariant linter (``repro.analysis``): fixture
coverage per rule, baseline/suppression semantics, CLI exit codes, the
CountingJit retrace sanitizer, the opt-in fleet NaN guard, and
regression tests for the WAL-ordering violations the linter caught in
the fleet engine and the service."""
import json
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ALL_RULES, RULE_IDS
from repro.analysis.baseline import Baseline
from repro.analysis.core import load_project
from repro.analysis.report import Report, run_rules
from repro.analysis.runtime import (FiniteGuard, NonFiniteError,
                                    install_nan_guard, nan_guard_stats)
from repro.bo.sampler import FleetSampler
from repro.bo.space import BoxSpace
from repro.core.mso import MsoOptions
from repro.engine.cache import (CountingJit, merge_retrace_reports,
                                retrace_report)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"

# rule id -> fixture stem; <stem>_bad.py must trigger the rule,
# <stem>_ok.py must be finding-free
RULE_FIXTURES = {
    "wal-before-state": "wal_before_state",
    "use-after-donate": "use_after_donate",
    "recompile-hazard": "recompile_hazard",
    "host-leak-into-trace": "host_leak",
    "nan-hazard": "nan_hazard",
}


def _lint(*paths):
    proj = load_project(list(paths), root=REPO, exclude=())
    return run_rules(proj, ALL_RULES)


# ========================================================== fixtures
@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_bad_fixture_triggers_exactly_its_rule(rule):
    findings = _lint(FIXTURES / f"{RULE_FIXTURES[rule]}_bad.py")
    assert findings, f"{rule}: bad fixture produced no findings"
    assert {f.rule for f in findings} == {rule}, \
        f"{rule}: cross-rule contamination: {[f.rule for f in findings]}"
    for f in findings:
        assert f.line > 0 and f.file.endswith("_bad.py") and f.message


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_ok_fixture_is_clean(rule):
    findings = _lint(FIXTURES / f"{RULE_FIXTURES[rule]}_ok.py")
    assert findings == [], \
        f"{rule}: ok fixture flagged: {[(f.rule, f.line) for f in findings]}"


def test_every_rule_id_has_fixtures():
    """Meta-test: a new rule without trigger/non-trigger fixtures is a
    test failure, not a silent coverage gap."""
    assert set(RULE_FIXTURES) == set(RULE_IDS)
    for rule, stem in RULE_FIXTURES.items():
        for suffix in ("bad", "ok"):
            assert (FIXTURES / f"{stem}_{suffix}.py").exists(), \
                f"rule {rule} is missing its {suffix} fixture"


def test_wal_fixture_finds_all_three_patterns():
    """evict-before-journal, scalar-flag-before-journal, and
    slot-table-growth-before-journal are each caught."""
    findings = _lint(FIXTURES / "wal_before_state_bad.py")
    assert len(findings) == 3
    assert {f.func.rsplit(".", 1)[-1] for f in findings} == {
        "evict_then_journal", "flag_then_journal", "install_then_journal"}


def test_recompile_fixture_severities():
    """Live-state keying is an error; per-call construction a warning."""
    findings = _lint(FIXTURES / "recompile_hazard_bad.py")
    sev = {f.func.rsplit(".", 1)[-1]: f.severity for f in findings}
    assert sev["ask"] == "error"
    assert sev["rebuild_per_call"] == "warning"


# ============================================ baseline / suppression
def _one_bad_finding():
    return _lint(FIXTURES / "use_after_donate_bad.py")[0]


def test_baseline_suppresses_with_reason():
    f = _one_bad_finding()
    bl = Baseline(entries=[
        Baseline.entry_for(f, "fixture: intentionally bad")])
    proj = load_project([FIXTURES / "use_after_donate_bad.py"],
                        root=REPO, exclude=())
    rep = Report(proj, [f], bl)
    assert not rep.open and len(rep.baselined) == 1 and not rep.failed
    assert rep.baselined[0]["reason"] == "fixture: intentionally bad"


def test_baseline_without_reason_fails():
    f = _one_bad_finding()
    bl = Baseline(entries=[Baseline.entry_for(f, "")])
    proj = load_project([FIXTURES / "use_after_donate_bad.py"],
                        root=REPO, exclude=())
    rep = Report(proj, [f], bl)
    assert rep.failed
    assert any(g.rule == "baseline-missing-reason" for g in rep.open)


def test_stale_baseline_entries_surface(tmp_path):
    """An entry whose source line changed/disappeared no longer matches
    any finding and is reported for pruning."""
    bl = Baseline(entries=[
        {"rule": "wal-before-state", "file": "gone.py", "func": "X.y",
         "snippet": "self.q.pop()", "reason": "was real once"}])
    proj = load_project([FIXTURES / "wal_before_state_ok.py"],
                        root=REPO, exclude=())
    rep = Report(proj, [], bl)
    assert len(rep.stale_baseline) == 1
    assert rep.stale_baseline[0]["file"] == "gone.py"


def test_inline_allow_requires_reason(tmp_path):
    src = (FIXTURES / "wal_before_state_bad.py").read_text()
    with_reason = src.replace(
        "self.studies.pop(st.sid)",
        "self.studies.pop(st.sid)  "
        "# repro: allow[wal-before-state] fixture test")
    p = tmp_path / "allowed.py"
    p.write_text(with_reason)
    proj = load_project([p], root=REPO, exclude=())
    rep = Report(proj, run_rules(proj, ALL_RULES),
                 Baseline(path=tmp_path / "b.json"))
    assert len(rep.suppressed) == 1       # the allowed line
    assert len(rep.open) == 2             # the other two violations
    assert rep.suppressed[0]["reason"] == "fixture test"
    # a bare allow comment with no reason does NOT suppress
    no_reason = src.replace(
        "self.studies.pop(st.sid)",
        "self.studies.pop(st.sid)  # repro: allow[wal-before-state]")
    p2 = tmp_path / "bare.py"
    p2.write_text(no_reason)
    proj2 = load_project([p2], root=REPO, exclude=())
    rep2 = Report(proj2, run_rules(proj2, ALL_RULES),
                  Baseline(path=tmp_path / "b2.json"))
    assert len(rep2.open) == 3 and rep2.failed


# ================================================================ CLI
def _run_cli(*argv):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_cli_clean_on_tree():
    """The shipped tree has no open findings: every real violation is
    fixed, every false positive baselined with a reason."""
    res = _run_cli("--check")
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_nonzero_on_seeded_violations(tmp_path):
    out = tmp_path / "report.json"
    res = _run_cli("tests/analysis_fixtures", "--no-baseline",
                   "--check", "--json", str(out))
    assert res.returncode == 1, res.stdout + res.stderr
    rep = json.loads(out.read_text())
    got = {f["rule"] for f in rep["open"]}
    assert got == set(RULE_IDS), \
        f"every rule must fire on its fixture; missing {set(RULE_IDS) - got}"
    for f in rep["open"]:
        assert f["file"] and f["line"] and f["severity"] and f["message"]


# ================================================= retrace sanitizer
def test_retrace_cause_static_arg():
    """A mis-keyed program (python value marked static) reports
    `static-arg` as the retrace cause — the exact diagnosis the
    compile-economy assertions need when they trip."""
    prog = CountingJit(lambda a, b: a * b, static_argnums=(1,),
                       name="miskeyed")
    x = jnp.ones((3,))
    prog(x, 2.0)
    prog(x, 3.0)                         # same shapes; new static value
    summ = prog.retrace_summary()
    assert summ["causes"] == {"first-trace": 1, "static-arg": 1}
    ev = summ["events"][-1]
    assert ev["cause"] == "static-arg" and ev["program"] == "miskeyed"


def test_retrace_cause_shape_and_dtype():
    prog = CountingJit(lambda a: a * 2)
    prog(jnp.ones((3,)))
    prog(jnp.ones((5,)))
    prog(jnp.ones((5,), dtype=jnp.int32))
    causes = prog.retrace_summary()["causes"]
    assert causes["first-trace"] == 1 and causes["shape"] == 1 \
        and causes["dtype"] == 1


def test_retrace_cache_hit_records_nothing():
    prog = CountingJit(lambda a: a + 1)
    for _ in range(4):
        prog(jnp.ones((2,)))
    assert prog.n_compiles == 1
    assert prog.retrace_summary()["causes"] == {"first-trace": 1}


def test_retrace_report_and_merge():
    a = CountingJit(lambda x: x)
    b = CountingJit(lambda x: x * 2)
    a(jnp.ones((2,)))
    b(jnp.ones((2,)))
    b(jnp.ones((4,)))
    rep = retrace_report({"a": a, "b": b})
    assert rep["causes"] == {"first-trace": 2, "shape": 1}
    assert rep["by_program"]["b"]["shape"] == 1
    merged = merge_retrace_reports(rep, {"causes": {"shape": 2},
                                         "by_program": {"c": {"shape": 2}}})
    assert merged["causes"]["shape"] == 3 and "c" in merged["by_program"]


# ======================================================== NaN guard
class _EngineStub:
    def __init__(self):
        self._full_jit = CountingJit(lambda x: x * 2, name="full")
        self._incr_jit = CountingJit(lambda x: x + 1, name="incr")
        self._mso_jit = CountingJit(lambda x: x - 1, name="mso")


def test_nan_guard_passes_finite_and_keeps_attrs():
    eng = _EngineStub()
    install_nan_guard(eng)
    out = eng._full_jit(jnp.ones((3,)))
    np.testing.assert_array_equal(np.asarray(out), 2.0 * np.ones(3))
    # CountingJit surface still reachable through the guard
    assert eng._full_jit.n_compiles == 1
    stats = nan_guard_stats(eng)
    assert stats["installed"] and stats["n_guard_checks"] == 1


def test_nan_guard_raises_naming_program_and_leaf():
    eng = _EngineStub()
    install_nan_guard(eng)
    bad = jnp.array([1.0, jnp.nan, 3.0])
    with pytest.raises(NonFiniteError, match="full"):
        eng._full_jit(bad)
    with pytest.raises(NonFiniteError, match="inputs"):
        eng._mso_jit(bad)


def test_nan_guard_catches_nonfinite_outputs():
    eng = _EngineStub()
    eng._incr_jit = CountingJit(lambda x: x / 0.0, name="incr")
    install_nan_guard(eng)
    with pytest.raises(NonFiniteError, match="outputs"):
        eng._incr_jit(jnp.ones((2,)))


def test_nan_guard_idempotent():
    eng = _EngineStub()
    g1 = list(install_nan_guard(eng))
    g2 = list(install_nan_guard(eng))
    assert [id(a) for a in g1] == [id(b) for b in g2]
    assert isinstance(eng._full_jit, FiniteGuard) \
        and not isinstance(eng._full_jit._inner, FiniteGuard)


# ==================================== WAL ordering regression tests
#
# PR 9's linter found five real write-ahead violations in the fleet
# engine (_shed, _install, _park, _quarantine_newest, observe's
# migration) and one in the service (_retry): state was mutated before
# the journal append, so a crash inside the append lost the mutation
# silently.  Each test injects a journal whose append always fails and
# asserts the state transition did NOT happen.

class _ExplodingJournal:
    def append(self, record):
        raise RuntimeError("journal I/O failed")


def _small_fleet(rounds=4):
    sp = BoxSpace.cube(2, 0.0, 1.0)
    fs = FleetSampler([sp] * 2, seed=0, n_startup_trials=3, n_restarts=2,
                      pad_multiple=4, slots=2, posterior_backend="xla",
                      refit_interval=2, warm_start=False,
                      mso_options=MsoOptions(maxiter=10, pgtol=1e-1))
    for _ in range(rounds):
        for i, t in enumerate(fs.ask_all()):
            fs.tell(i, t.trial_id, float(np.sum((t.x - 0.3) ** 2)))
    return fs


@pytest.fixture(scope="module")
def driven_fleet():
    return _small_fleet()


def test_wal_shed_not_applied_on_journal_failure(driven_fleet):
    fleet = driven_fleet.fleet
    st = fleet._studies[0]
    fleet.journal = _ExplodingJournal()
    try:
        with pytest.raises(RuntimeError):
            fleet._shed(st, "torn append")
        assert st.shed is None, "shed applied before its WAL record"
    finally:
        fleet.journal = None


def test_wal_park_not_applied_on_journal_failure(driven_fleet):
    fleet = driven_fleet.fleet
    st = fleet._studies[0]
    blk_before, result_before = st.block, st.result
    fleet.journal = _ExplodingJournal()
    try:
        with pytest.raises(RuntimeError):
            fleet._park(st, "torn append")
        assert st.parked is None
        assert st.block is blk_before and st.result is result_before
    finally:
        fleet.journal = None


def test_wal_quarantine_not_applied_on_journal_failure(driven_fleet):
    fleet = driven_fleet.fleet
    st = fleet._studies[1]
    n_before = (len(st.xs), len(st.ys), len(st.tags))
    fleet.journal = _ExplodingJournal()
    try:
        with pytest.raises(RuntimeError):
            fleet._quarantine_newest(st, "torn append")
        assert (len(st.xs), len(st.ys), len(st.tags)) == n_before, \
            "observation dropped before its quarantine WAL record"
    finally:
        fleet.journal = None


def test_wal_migration_not_applied_on_journal_failure():
    fs = _small_fleet(rounds=4)
    fleet = fs.fleet
    st = fleet._studies[0]
    while st.n < 4:                      # fill the pad bucket exactly
        for i, t in enumerate(fs.ask_all()):
            fs.tell(i, t.trial_id, float(np.sum((t.x - 0.3) ** 2)))
    assert st.block is not None and st.n == 4
    fleet.journal = _ExplodingJournal()
    try:
        with pytest.raises(RuntimeError):
            # 5th observation crosses the pad bucket -> migration path
            fleet.observe(0, np.full(2, 0.5), 1.0, tag=99)
        assert st.block is not None, \
            "slot evicted before the migrate WAL record"
        assert st not in fleet._queue
    finally:
        fleet.journal = None


def test_wal_install_not_applied_on_journal_failure(driven_fleet):
    fleet = driven_fleet.fleet
    st = fleet._studies[1]
    blk, slot = st.block, st.slot
    assert blk is not None
    fleet._evict(st)                     # not itself a journaled op
    fleet._queue.remove(st)
    fleet.journal = _ExplodingJournal()
    try:
        with pytest.raises(RuntimeError):
            fleet._install(st, blk, slot)
        assert blk.studies[slot] is None and st.block is None, \
            "slot table updated before the admit WAL record"
    finally:
        fleet.journal = None
        fleet._install(st, blk, slot)    # restore for other tests


def test_wal_service_retry_not_applied_on_journal_failure():
    from repro.serve.bo_service import BOService, TenantConfig

    fs = _small_fleet(rounds=0)
    svc = BOService(fs, [TenantConfig("a", weight=1.0, studies=(0, 1))],
                    max_retries=3, backoff_base=0.01, backoff_cap=0.1)
    req = svc.submit_ask("a", 0)
    req.attempts = 1                     # first transient failure
    state_before, delayed_before = req.state, len(svc._delayed)
    fs.journal = _ExplodingJournal()     # BOService journals via fs
    try:
        with pytest.raises(RuntimeError):
            svc._retry(req, RuntimeError("transient"))
        assert req.state == state_before and req.not_before is None
        assert len(svc._delayed) == delayed_before, \
            "request delayed before its svc_retry WAL record"
    finally:
        fs.journal = None
