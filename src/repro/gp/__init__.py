from repro.gp.kernels import KernelParams, matern52, rbf, gram
from repro.gp.gpr import (GPState, cholesky_update, fit_gram, kinv_update,
                          log_marginal_likelihood,
                          log_marginal_likelihood_masked, pad_gp, predict,
                          with_kinv)
from repro.gp.fit import (fit_gp, incremental_update, standardize,
                          standardize_masked)
