"""Paper Figures 1, 3, 4 — off-diagonal artifacts in the QN inverse-Hessian.

Setup (paper §3): Rosenbrock, D=5, x ∈ [0,3]^D, B restarts.  Optimize with
(a) SEQ. OPT. (per-restart solver) and (b) C-BE (one solver over the
flattened B·D vector of the summed objective), then compare the solver's
final inverse-Hessian approximation against the true inverse Hessian:

  e_rel(H)     = ||H - H_true||_F / ||H_true||_F        (figure subtitles)
  offdiag_mass = ||offdiag-blocks(H)||_F / ||H||_F      (the artifact)

SEQ's H is block-diagonal by construction (mass ≡ 0); the paper's claim is
that C-BE's is not, for both L-BFGS-B (m=10) and full BFGS.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp           # noqa: E402
import numpy as np                # noqa: E402
from scipy.optimize import minimize  # noqa: E402


def rosen_np(x):
    return float(np.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2
                        + (1.0 - x[:-1]) ** 2))


def rosen_grad_np(x):
    g = np.zeros_like(x)
    xm = x[1:-1]
    g[1:-1] = (200 * (xm - x[:-2] ** 2) - 400 * xm * (x[2:] - xm ** 2)
               - 2 * (1 - xm))
    g[0] = -400 * x[0] * (x[1] - x[0] ** 2) - 2 * (1 - x[0])
    g[-1] = 200 * (x[-1] - x[-2] ** 2)
    return g


def _sum_obj(z, B, D):
    X = z.reshape(B, D)
    return float(sum(rosen_np(X[b]) for b in range(B)))


def _sum_grad(z, B, D):
    X = z.reshape(B, D)
    return np.concatenate([rosen_grad_np(X[b]) for b in range(B)])


def true_inverse_hessian(X):
    """Block-diagonal inverse Hessian of the summed Rosenbrock at X."""
    B, D = X.shape

    def rosen_jnp(x):
        return jnp.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2
                       + (1.0 - x[:-1]) ** 2)

    H = np.zeros((B * D, B * D))
    for b in range(B):
        Hb = np.asarray(jax.hessian(rosen_jnp)(jnp.asarray(X[b])))
        H[b * D:(b + 1) * D, b * D:(b + 1) * D] = np.linalg.inv(Hb)
    return H


def offdiag_mass(H, B, D):
    mask = np.ones_like(H)
    for b in range(B):
        mask[b * D:(b + 1) * D, b * D:(b + 1) * D] = 0.0
    return float(np.linalg.norm(H * mask) / max(np.linalg.norm(H), 1e-30))


def run(B=3, D=5, method="L-BFGS-B", seed=0, maxiter=500):
    rng = np.random.default_rng(seed)
    X0 = rng.uniform(0.0, 3.0, (B, D))
    bounds = [(0.0, 3.0)] * D
    opts = dict(maxiter=maxiter)
    if method == "L-BFGS-B":
        opts.update(maxcor=10, gtol=1e-10, ftol=0.0)

    # SEQ. OPT.: independent solvers → assemble block-diagonal H
    H_seq = np.zeros((B * D, B * D))
    X_fin = np.zeros_like(X0)
    for b in range(B):
        r = minimize(rosen_np, X0[b], jac=rosen_grad_np, method=method,
                     bounds=bounds if method == "L-BFGS-B" else None,
                     options=opts)
        X_fin[b] = r.x
        hb = r.hess_inv.todense() if method == "L-BFGS-B" else r.hess_inv
        H_seq[b * D:(b + 1) * D, b * D:(b + 1) * D] = hb

    # C-BE: one solver over the flattened summed objective
    r = minimize(lambda z: _sum_obj(z, B, D), X0.reshape(-1),
                 jac=lambda z: _sum_grad(z, B, D), method=method,
                 bounds=bounds * B if method == "L-BFGS-B" else None,
                 options=opts)
    H_cbe = r.hess_inv.todense() if method == "L-BFGS-B" else r.hess_inv
    X_cbe = r.x.reshape(B, D)

    H_true_seq = true_inverse_hessian(X_fin)
    H_true_cbe = true_inverse_hessian(X_cbe)

    def e_rel(H, Ht):
        return float(np.linalg.norm(H - Ht) / np.linalg.norm(Ht))

    return {
        "method": method, "B": B, "D": D,
        "e_rel_seq": e_rel(H_seq, H_true_seq),
        "e_rel_cbe": e_rel(np.asarray(H_cbe), H_true_cbe),
        "offdiag_seq": offdiag_mass(H_seq, B, D),
        "offdiag_cbe": offdiag_mass(np.asarray(H_cbe), B, D),
        "offdiag_true": offdiag_mass(H_true_cbe, B, D),
    }


def main(full=False):
    rows = []
    cases = [("L-BFGS-B", 3), ("BFGS", 3), ("BFGS", 10)]   # Fig 1, 3, 4
    for method, B in cases:
        r = run(B=B, method=method)
        rows.append(r)
        print(f"offdiag,{method},B={r['B']},"
              f"e_rel_seq={r['e_rel_seq']:.3f},"
              f"e_rel_cbe={r['e_rel_cbe']:.3f},"
              f"offdiag_seq={r['offdiag_seq']:.4f},"
              f"offdiag_cbe={r['offdiag_cbe']:.4f}")
    return rows


if __name__ == "__main__":
    main()
