"""Fixture: masked twin of ``nan_hazard_bad`` — guarded denominators,
non-finite literals only behind masking ops.  Zero ``nan-hazard``
findings."""
import jax.numpy as jnp
from jax import lax


def normalize_loop(x):
    def cond(carry):
        i, v = carry
        return i < 8

    def body(carry):
        i, v = carry
        denom = jnp.maximum(v.sum(), 1e-12)
        scaled = v / denom
        masked = jnp.where(jnp.isfinite(scaled), scaled, 0.0)
        return i + 1, masked

    return lax.while_loop(cond, body, (0, x))
