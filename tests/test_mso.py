"""MSO strategy tests — including the paper's central claims C2/C3."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mso import (MsoOptions, STRATEGIES, maximize_acqf,
                            maximize_acqf_closure)


def neg_rosen_acq(state, X):
    del state
    return -jax.vmap(lambda x: jnp.sum(
        100.0 * (x[1:] - x[:-1] ** 2) ** 2
        + (1.0 - x[:-1]) ** 2))(X)


@pytest.fixture(scope="module")
def setup():
    B, D = 8, 5
    rng = np.random.default_rng(0)
    x0 = rng.uniform(0, 3, (B, D))
    opts = MsoOptions(m=10, maxiter=200, pgtol=1e-8)
    return x0, opts


def run(strategy, x0, opts):
    return maximize_acqf(neg_rosen_acq, x0, 0.0, 3.0, acq_state=None,
                         strategy=strategy, options=opts)


def test_c3_dbe_reproduces_seq_trajectories(setup):
    """Paper §4: D-BE per-restart trajectories == SEQ. OPT. under identical
    init/termination (same solver, same evals)."""
    x0, opts = setup
    seq = run("seq", x0, opts)
    dbe = run("dbe", x0, opts)
    np.testing.assert_array_equal(seq.x, dbe.x)          # bitwise!
    np.testing.assert_array_equal(seq.n_iters, dbe.n_iters)


def test_c3_vectorized_matches_seq_quality(setup):
    """The device-resident D-BE reaches the same optima with comparable
    iteration counts (different solver implementation → not bitwise)."""
    x0, opts = setup
    seq = run("seq", x0, opts)
    vec = run("dbe_vec", x0, opts)
    assert abs(vec.best_acq - seq.best_acq) < 1e-6
    assert np.median(vec.n_iters) <= np.median(seq.n_iters) * 1.5


def test_c2_cbe_iteration_inflation(setup):
    """Paper §3: C-BE's off-diagonal artifacts inflate the QN iteration
    count substantially versus D-BE at B=8."""
    x0, opts = setup
    dbe = run("dbe", x0, opts)
    cbe = run("cbe", x0, opts)
    assert np.median(cbe.n_iters) > 2.0 * np.median(dbe.n_iters), (
        np.median(cbe.n_iters), np.median(dbe.n_iters))


def test_dbe_fewer_eval_rounds_than_seq(setup):
    """Batching: D-BE needs ~B× fewer evaluation ROUNDS than SEQ (same
    total per-restart evals) — the wall-clock mechanism of the paper."""
    x0, opts = setup
    seq = run("seq", x0, opts)
    dbe = run("dbe", x0, opts)
    assert dbe.n_rounds * 3 < seq.n_rounds
    assert int(np.sum(dbe.n_evals)) == int(np.sum(seq.n_evals))


def test_all_strategies_reach_optimum(setup):
    x0, opts = setup
    for s in STRATEGIES:
        res = run(s, x0, opts)
        assert res.best_acq > -1e-6, (s, res.best_acq)


def test_closure_api():
    acq = jax.vmap(lambda x: -jnp.sum((x - 0.5) ** 2))
    x0 = np.random.default_rng(1).uniform(0, 1, (4, 3))
    res = maximize_acqf_closure(acq, x0, 0.0, 1.0, strategy="dbe_vec",
                                options=MsoOptions(maxiter=50, pgtol=1e-8))
    np.testing.assert_allclose(res.best_x, 0.5, atol=1e-5)


def test_closure_api_forwards_engine():
    """Passing engine= reuses one compiled plane across calls instead of
    retracing per fresh closure; an engine built from a DIFFERENT
    closure is rejected (it would evaluate its own acq_fn)."""
    from repro.core.mso import closure_engine

    acq = jax.vmap(lambda x: -jnp.sum((x - 0.5) ** 2))
    eng = closure_engine(acq)
    rng = np.random.default_rng(1)
    opts = MsoOptions(maxiter=50, pgtol=1e-8)
    for _ in range(3):
        x0 = rng.uniform(0, 1, (4, 3))
        res = maximize_acqf_closure(acq, x0, 0.0, 1.0, strategy="dbe_vec",
                                    options=opts, engine=eng)
        np.testing.assert_allclose(res.best_x, 0.5, atol=1e-5)
    assert eng.n_compiles == 1      # one lockstep trace, shared by 3 calls
    assert res.engine_stats["n_compiles"] == 1

    other = jax.vmap(lambda x: -jnp.sum(x ** 2))
    with pytest.raises(ValueError, match="different closure"):
        maximize_acqf_closure(other, rng.uniform(0, 1, (4, 3)), 0.0, 1.0,
                              strategy="dbe_vec", options=opts, engine=eng)


def test_shrinking_active_set():
    """Converged restarts leave the coroutine batch (paper's pruning)."""
    from repro.core import coroutine as co

    def be(X):
        f = np.sum((X - 0.5) ** 2, axis=1)
        g = 2.0 * (X - 0.5)
        return f, g

    rng = np.random.default_rng(2)
    # one restart starts AT the optimum: converges instantly
    x0 = rng.uniform(0, 1, (4, 3))
    x0[0] = 0.5
    out = co.run_dbe_coroutine(be, x0, np.zeros(3), np.ones(3),
                               m=10, maxiter=100, pgtol=1e-10)
    assert out.batch_sizes[0] == 4
    assert out.batch_sizes[-1] < 4
