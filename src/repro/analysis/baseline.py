"""Baseline (suppression) file: accepted findings with mandatory reasons.

The baseline is the triage record for pre-existing or by-design
findings: each entry pins one finding by its line-number-free identity
``(rule, file, func, snippet)`` and MUST carry a non-empty ``reason``.
A reasonless entry is itself reported as a finding — silencing without
saying why defeats the point of an invariant linter.

Matching is snippet-based (the stripped source line), so entries
survive unrelated edits that shift line numbers, and go stale (reported
as warnings) when the suppressed line itself changes or disappears.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .core import Finding, SEV_ERROR


class Baseline:
    def __init__(self, entries: Optional[List[dict]] = None,
                 path: Optional[Path] = None):
        self.path = path
        self.entries = entries or []
        self._index: Dict[Tuple[str, str, str, str], dict] = {}
        self._used: set = set()
        for e in self.entries:
            key = (e.get("rule", ""), e.get("file", ""),
                   e.get("func", ""), e.get("snippet", ""))
            self._index[key] = e

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls(path=path)
        data = json.loads(path.read_text())
        return cls(entries=data.get("entries", []), path=path)

    def save(self, path: Optional[Path] = None) -> None:
        p = path or self.path
        assert p is not None
        p.write_text(json.dumps(
            {"entries": sorted(self.entries,
                               key=lambda e: (e.get("rule", ""),
                                              e.get("file", ""),
                                              e.get("func", "")))},
            indent=2) + "\n")

    def match(self, finding: Finding) -> Optional[dict]:
        ent = self._index.get(finding.key())
        if ent is not None:
            self._used.add(finding.key())
        return ent

    def reasonless(self) -> List[Finding]:
        out = []
        for key, e in self._index.items():
            if not str(e.get("reason", "")).strip():
                out.append(Finding(
                    rule="baseline-missing-reason",
                    file=e.get("file", "?"), line=0, severity=SEV_ERROR,
                    message=(f"baseline entry for [{e.get('rule')}] in "
                             f"{e.get('func') or 'module'} has no reason; "
                             f"every suppression must say why"),
                    func=e.get("func", ""), snippet=e.get("snippet", "")))
        return out

    def stale(self) -> List[dict]:
        return [e for k, e in self._index.items() if k not in self._used]

    @staticmethod
    def entry_for(finding: Finding, reason: str) -> dict:
        return {"rule": finding.rule, "file": finding.file,
                "func": finding.func, "snippet": finding.snippet,
                "reason": reason}
