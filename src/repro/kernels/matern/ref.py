"""Pure-jnp oracles for the Matérn-5/2 Pallas kernels (no Pallas)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

SQRT5 = 2.2360679774997896

VAR_FLOOR = 1e-16          # matches gpr.predict's posterior-variance clamp


def matern52_gram_ref(x1: jax.Array, x2: jax.Array, inv_lengthscale: jax.Array,
                      amplitude: jax.Array) -> jax.Array:
    """k(x1, x2): (n1, n2).  x*: (n*, D); inv_lengthscale: (D,); amplitude: ()."""
    a = x1 * inv_lengthscale
    b = x2 * inv_lengthscale
    d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
          - 2.0 * (a @ b.T))
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-36)
    return amplitude * (1.0 + SQRT5 * r + (5.0 / 3.0) * d2) * \
        jnp.exp(-SQRT5 * r)


def matern52_posterior_ref(xq: jax.Array, xt: jax.Array, alpha: jax.Array,
                           kinv: jax.Array, inv_lengthscale: jax.Array,
                           amplitude: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
    """Fused GP posterior oracle: ((q,) mean, (q,) variance).

    Quadratic-form formulation: ``mean = k* α``, ``var = σ_f² − k* K⁻¹ k*ᵀ``
    (diagonal), with ``kinv = K⁻¹`` precomputed once per fit.  Equal in
    exact arithmetic to the Cholesky form in ``gp.gpr.predict``; this is
    the formulation the Pallas kernel fuses (one cross-gram build feeding
    both epilogues, nothing written back to HBM but the two (q,) vectors).
    """
    k_star = matern52_gram_ref(xq, xt, inv_lengthscale, amplitude)   # (q, n)
    mean = k_star @ alpha
    quad = jnp.sum((k_star @ kinv) * k_star, axis=-1)
    var = jnp.maximum(amplitude - quad, VAR_FLOOR)
    return mean, var
