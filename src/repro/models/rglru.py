"""Griffin/RecurrentGemma recurrent block: causal conv + RG-LRU.

RG-LRU is a *diagonal* gated linear recurrence:
    a_t = exp(-c · softplus(Λ) · σ(r_t))
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
Diagonality makes it associative ⇒ training runs as one
``lax.associative_scan`` over the sequence (parallel depth log S — the
TPU-native answer to the paper-family's CUDA linear-scan kernels), while
decode keeps an O(1) carried state.  This is what makes the long_500k cell
feasible for this family (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import box, constrain
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init

Array = jax.Array

_C = 8.0      # Griffin's fixed recurrence sharpness constant


def init_recurrent_block(key, cfg: ModelConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    p = {
        # two input branches (gate / recurrent)
        "w_gate_in": box(_dense_init(k1, (d, w), dtype, d), "embed", "lru"),
        "w_rec_in": box(_dense_init(k2, (d, w), dtype, d), "embed", "lru"),
        "w_out": box(_dense_init(k3, (w, d), dtype, w), "lru", "embed"),
        # temporal conv (depthwise, width cfg.conv_width)
        "conv_w": box(_dense_init(k4, (cfg.conv_width, w), dtype,
                                  cfg.conv_width), None, "lru"),
        "conv_b": box(jnp.zeros((w,), dtype), "lru"),
        # RG-LRU gates
        "w_input_gate": box(_dense_init(k5, (w, w), dtype, w), "lru", None),
        "w_rec_gate": box(_dense_init(k6, (w, w), dtype, w), "lru", None),
        "lambda_param": box(jnp.full((w,), 0.7, jnp.float32), "lru"),
    }
    return p


def _causal_conv(x: Array, w: Array, b: Array,
                 state: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """Depthwise causal conv.  x: (B, S, W); w: (K, W).

    ``state``: (B, K-1, W) trailing context from the previous segment
    (decode); returns (out, new_state).
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, W)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else state
    return out + b, new_state


def _rg_lru(x: Array, r: Array, i: Array, lam: Array,
            h0: Optional[Array] = None) -> Tuple[Array, Array]:
    """x/r/i: (B, S, W) → (h, h_last).  Associative scan over S."""
    cdt = jnp.promote_types(x.dtype, jnp.float32)
    log_a = -_C * jax.nn.softplus(lam.astype(cdt))[None, None, :] * \
        jax.nn.sigmoid(r.astype(cdt))                  # (B, S, W) ≤ 0
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i.astype(cdt)) * x.astype(cdt)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(cdt))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def apply_recurrent_block(p: dict, cfg: ModelConfig, x: Array,
                          state: Optional[dict] = None
                          ) -> Tuple[Array, Optional[dict]]:
    """x: (B, S, D) → (y, new_state).  state carries (conv, h) for decode."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"].value))
    rec = jnp.einsum("bsd,dw->bsw", x, p["w_rec_in"].value)
    rec = constrain(rec, "batch", None, "lru")

    conv_state = state["conv"] if state is not None else None
    rec, new_conv = _causal_conv(rec, p["conv_w"].value,
                                 p["conv_b"].value, conv_state)

    r = jnp.einsum("bsw,wu->bsu", rec, p["w_rec_gate"].value)
    i = jnp.einsum("bsw,wu->bsu", rec, p["w_input_gate"].value)
    h0 = state["h"] if state is not None else None
    h, h_last = _rg_lru(rec, r, i, p["lambda_param"].value, h0)

    y = jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"].value)
    y = constrain(y, "batch", None, None)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "h": h_last}
    return y, new_state


def init_recurrent_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
