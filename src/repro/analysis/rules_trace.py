"""Trace-discipline rules: recompile-hazard and host-leak-into-trace.

recompile-hazard — "never key a program on live studies" (ROADMAP:
compile-economy invariants).  A jit cache key may depend on the padded
shape bucket and slot count, never on live-study count, occupancy,
tenancy/QoS state, or mesh placement; those change every step and each
distinct value mints a fresh executable.  Flagged:

* live-state expressions (``len(self._studies)``, ``self._device_
  occupancy()``, a bare ``self._rung`` …) appearing *as arguments* to a
  jit-wrapped call — Python scalars become trace constants, so every new
  value retraces;
* functions handed to ``CountingJit``/``jax.jit`` whose bodies read
  live scheduler state (closure capture bakes it into the trace);
* jit wrappers constructed outside ``__init__``/module scope (warning:
  a per-call wrapper defeats the cache entirely).

host-leak-into-trace — "faults never traced / host state stays host"
(ROADMAP: fleet + robustness invariants).  Inside the traced closure
(functions reachable from any jit/vmap/while_loop root) flag:

* ``.item()`` / ``float()/int()/bool()`` on non-constants /
  ``np.asarray``-family calls — host sync inside the trace;
* Python ``if``/``while``/``assert`` on values that are neither static
  jit params nor shape/dtype/config attributes — concretization errors
  or silent trace specialization;
* reads of host-side robustness state (``journal``, ``fault_injector``,
  recovery/quarantine/service fields) — the fault plane must never
  leak into compiled code.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .core import (Finding, ModuleInfo, Project, Rule, ancestors,
                   call_target, dotted_name, last_segment)

# host scheduler / service state a program may never be keyed on
LIVE_STATE_ATTRS = {
    "_studies", "_queue", "_blocks", "samplers", "_delayed", "_tenants",
    "trials", "studies", "queue", "_rung", "deficit", "pending",
    "_lat", "n_live",
}
LIVE_STATE_CALLS = {"_device_occupancy", "queue_depth", "live_studies"}

# host-only robustness state that must never be read under a trace
HOST_STATE_ATTRS = {
    "journal", "fault_injector", "_rung", "shed", "parked", "degraded",
    "_draining", "_delayed", "recovered", "_preempt",
}

# names conventionally static inside traced code (configs, plans, axes)
STATIC_NAME_ALLOW = {
    "self", "cls", "cfg", "config", "opts", "options", "plan", "backend",
    "dtype", "dt", "axis", "axis_name", "mesh", "spec", "kernel",
    "fit_opts", "interpret", "debug", "precision", "mode",
}
# attribute tails that are static facts about an array/config, fine to
# branch on at trace time
STATIC_ATTR_TAILS = {
    "ndim", "shape", "dtype", "size", "name", "axis_names", "devices",
    "maxiter", "m", "dim", "n_restarts", "batch", "bucket", "slots",
}
STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "callable",
                "issubclass", "range", "min", "max", "tuple", "abs"}

NUMPY_HOST_CALLS = {"asarray", "array", "ascontiguousarray"}


def _jit_registry(module: ModuleInfo) -> Set[str]:
    """Names bound to CountingJit/jax.jit objects in this module."""
    out: Set[str] = set()
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if call_target(node.value) in ("CountingJit", "jit"):
                for t in node.targets:
                    name = t.attr if isinstance(t, ast.Attribute) else (
                        t.id if isinstance(t, ast.Name) else None)
                    if name:
                        out.add(name)
    return out


def _live_state_expr(node: ast.AST) -> Optional[str]:
    """Describe the first live-state read inside ``node``, if any."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            tgt = call_target(n)
            if tgt in LIVE_STATE_CALLS:
                return f"{dotted_name(n.func) or tgt}()"
        if isinstance(n, ast.Attribute) and n.attr in LIVE_STATE_ATTRS:
            par = getattr(n, "_parent", None)
            if isinstance(par, ast.Attribute):
                continue
            return dotted_name(n) or n.attr
    return None


class RecompileHazardRule(Rule):
    id = "recompile-hazard"
    severity = "error"
    doc = ("jit cache keys must not derive from live-study count, "
           "occupancy, tenancy, or mesh placement")

    def run(self, module: ModuleInfo, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        registry = _jit_registry(module)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            tgt = call_target(node)
            qual = project.enclosing_function(node)
            if tgt in registry and tgt not in ("CountingJit", "jit"):
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    desc = _live_state_expr(arg)
                    if desc is not None:
                        findings.append(module.finding(
                            self, arg,
                            f"argument derives from live scheduler state "
                            f"({desc}) in call to jit program {tgt} — "
                            f"cache key must not depend on live studies",
                            func=qual))
            if tgt in ("CountingJit", "jit"):
                # closure capture of live state by the traced fn
                if node.args:
                    for fi in project.resolve(node.args[0], module):
                        desc = _live_state_expr(fi.node)
                        if desc is not None:
                            findings.append(module.finding(
                                self, node,
                                f"function {fi.qualname} passed to {tgt} "
                                f"reads live scheduler state ({desc}); "
                                f"closure capture bakes it into the "
                                f"compiled program",
                                func=qual))
                # construction site discipline
                encl = qual.rsplit(".", 1)[-1] if qual else ""
                if qual and encl != "__init__" \
                        and not encl.startswith(("_build", "_make", "make_")):
                    findings.append(module.finding(
                        self, node,
                        f"{tgt} constructed inside {qual}; per-call jit "
                        f"wrappers defeat the compile cache — build "
                        f"programs once in __init__/module scope",
                        func=qual, severity="warning"))
        return findings


def _is_static_test(test: ast.AST, static_params: Set[str]) -> bool:
    """True when every leaf of a Python-control-flow test is trace-static:
    constants, static jit params, config names, shape/dtype attributes,
    ``is None`` checks, and static builtins."""
    skip: set = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops):
            # identity tests (`x is None`) are structural facts about the
            # python call, static at trace time by construction
            for sub in ast.walk(n):
                skip.add(id(sub))
    for n in ast.walk(test):
        if id(n) in skip:
            continue
        if isinstance(n, ast.Name):
            par = getattr(n, "_parent", None)
            if isinstance(par, ast.Attribute):
                continue          # judged via the full attribute chain
            if isinstance(par, ast.Call) and par.func is n:
                if n.id in STATIC_CALLS:
                    continue
                return False
            if n.id in static_params or n.id in STATIC_NAME_ALLOW:
                continue
            return False
        if isinstance(n, ast.Attribute):
            par = getattr(n, "_parent", None)
            if isinstance(par, ast.Attribute):
                continue
            if isinstance(par, ast.Call) and par.func is n:
                continue          # method call: judged by its args
            chain = dotted_name(n)
            root = chain.split(".")[0] if chain else None
            if n.attr in STATIC_ATTR_TAILS:
                continue
            if root in static_params or root in STATIC_NAME_ALLOW:
                continue
            return False
    return True


class HostLeakRule(Rule):
    id = "host-leak-into-trace"
    severity = "error"
    doc = ("no host sync, Python control flow on traced values, or "
           "host-state reads inside the traced closure")

    def run(self, module: ModuleInfo, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            if not project.is_traced(node):
                continue
            fi = project.func_for_node(node)
            qual = fi.qualname if fi else getattr(node, "name", "<lambda>")
            static = fi.static_params if fi else set()
            self._check_traced(node, static, module, qual, findings,
                               project)
        return findings

    def _check_traced(self, fn, static: Set[str], module: ModuleInfo,
                      qual: str, findings: List[Finding],
                      project: Project) -> None:
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in ast.walk(fn):
            # don't double-report inside nested defs that are themselves
            # in the traced set (they get their own pass)
            if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and project.is_traced(node):
                continue
            if isinstance(node, ast.Call):
                tgt = call_target(node)
                if tgt == "item" and isinstance(node.func, ast.Attribute):
                    findings.append(module.finding(
                        self, node, ".item() inside traced code forces a "
                        "host sync per call", func=qual))
                elif tgt in ("float", "int", "bool") \
                        and isinstance(node.func, ast.Name) and node.args \
                        and not isinstance(node.args[0], ast.Constant):
                    findings.append(module.finding(
                        self, node,
                        f"{tgt}() on a traced value concretizes it on "
                        f"host inside the trace", func=qual))
                elif tgt in NUMPY_HOST_CALLS \
                        and isinstance(node.func, ast.Attribute) \
                        and last_segment(node.func.value) in ("np", "numpy"):
                    findings.append(module.finding(
                        self, node,
                        f"np.{tgt}() inside traced code pulls the value "
                        f"to host; use jnp", func=qual))
            elif isinstance(node, (ast.If, ast.While)):
                if not _is_static_test(node.test, static):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    findings.append(module.finding(
                        self, node.test,
                        f"Python `{kind}` on a non-static value inside "
                        f"traced code; use lax.cond/where or mark the "
                        f"argument static", func=qual))
            elif isinstance(node, ast.Assert):
                if not _is_static_test(node.test, static):
                    findings.append(module.finding(
                        self, node,
                        "assert on a traced value: either concretization "
                        "error or silently compiled away", func=qual))
            elif isinstance(node, ast.Attribute) \
                    and node.attr in HOST_STATE_ATTRS:
                par = getattr(node, "_parent", None)
                if isinstance(par, ast.Attribute):
                    continue
                findings.append(module.finding(
                    self, node,
                    f"host robustness state .{node.attr} read inside "
                    f"traced code; faults/recovery must stay outside "
                    f"the trace", func=qual))
