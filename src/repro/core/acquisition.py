"""Acquisition functions — numerically stable LogEI (Ament et al. 2023),
EI, and UCB — plus the batched-evaluation closure used by every MSO
strategy.

The paper's experiment setting (§5): LogEI over a GP with Matérn-5/2,
optimized by L-BFGS-B MSO.  ``make_logei`` returns the `(k, D) → (k,)`
batched acquisition the MSO drivers consume.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.gp.gpr import GPState, predict, predict_joint

Array = jax.Array

_C1 = 0.5 * math.log(2.0 * math.pi)          # log √(2π)
_INV_SQRT2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _log_phi(z):
    return -0.5 * z * z - _C1


_BRANCH = -25.0     # direct f64 eval is cancellation-safe above this


def log_h(z: Array) -> Array:
    """log(φ(z) + z·Φ(z)) — the LogEI kernel, stable over all z.

    Branches (double-where guarded so gradients stay finite):
      z > -25  : direct  log(φ(z) + zΦ(z)) — the cancellation error is
                 ~eps·φ/h ≈ eps·z², still ≤1e-12 relative at z=-25 (f64);
      z ≤ -25  : asymptotic from Φ(z) ~ φ(z)/(−z)·Σ(−1)ᵏ(2k−1)!!/z²ᵏ:
                 log h = log φ − 2·log|z| + log1p(−3u + 15u² − 105u³),
                 u = 1/z² (next term 945u⁴ ≤ 6e-9 at the branch point).
    """
    z_safe_hi = jnp.maximum(z, _BRANCH)         # direct-branch input
    phi = jnp.exp(_log_phi(z_safe_hi))
    # erfc keeps Φ relatively accurate in the far tail (0.5·(1+erf) has
    # only absolute accuracy there, which the φ+zΦ cancellation amplifies)
    Phi = 0.5 * jax.lax.erfc(-z_safe_hi / jnp.sqrt(2.0).astype(z.dtype))
    direct_arg = jnp.maximum(phi + z_safe_hi * Phi, 1e-300)
    direct = jnp.log(direct_arg)

    z_safe_lo = jnp.minimum(z, _BRANCH)         # asymptotic-branch input
    u = 1.0 / (z_safe_lo * z_safe_lo)
    asym = (_log_phi(z_safe_lo) - 2.0 * jnp.log(-z_safe_lo)
            + jnp.log1p(-3.0 * u + 15.0 * u * u - 105.0 * u * u * u))
    return jnp.where(z > _BRANCH, direct, asym)


def log_ei(mean: Array, var: Array, best: Array) -> Array:
    """log E[max(0, μ − best)] under N(μ, σ²) — maximization convention."""
    sigma = jnp.sqrt(var)
    z = (mean - best) / sigma
    return log_h(z) + 0.5 * jnp.log(var)


def ei(mean: Array, var: Array, best: Array) -> Array:
    sigma = jnp.sqrt(var)
    z = (mean - best) / sigma
    phi = jnp.exp(_log_phi(z))
    Phi = 0.5 * jax.lax.erfc(-z / jnp.sqrt(2.0).astype(z.dtype))
    return sigma * (phi + z * Phi)


def ucb(mean: Array, var: Array, beta: float = 2.0) -> Array:
    return mean + beta * jnp.sqrt(var)


AcqBatched = Callable[[Array], Array]   # (k, D) -> (k,)


def logei_acq(state, xb: Array) -> Array:
    """State-form LogEI for the MSO layer: ``state = (GPState, best)``.

    Module-level pure function ⇒ jit caches key on shapes only; the fitted
    GP flows through as a traced pytree (no per-trial recompilation).
    """
    gp, best = state
    mean, var = predict(gp, xb)
    return log_ei(mean, var, best)


def ucb_acq(state, xb: Array) -> Array:
    """State-form UCB: ``state = (GPState, beta)``."""
    gp, beta = state
    mean, var = predict(gp, xb)
    return mean + beta * jnp.sqrt(var)


def _log_softplus(x: Array) -> Array:
    """log(softplus(x)), stable over all x (→ x for x ≪ 0)."""
    sp = jax.nn.softplus(jnp.maximum(x, -30.0))
    return jnp.where(x < -30.0, x, jnp.log(sp + 1e-300))


def qlogei_acq(state, xb: Array, *, tau_max: float = 1e-2,
               tau_relu: float = 1e-3) -> Array:
    """Joint q-batch LogEI: ``state = (GPState, best, eps)``, xb (k, q, D).

    MC qLogEI in the smoothed formulation of Ament et al. 2023: for each
    candidate block the joint posterior over its q points is sampled with
    *fixed* base draws ``eps`` (S, q) — common random numbers keep the
    surface deterministic and differentiable for the QN optimizers — and
    the max over the q points / relu are softened by ``logsumexp`` /
    ``softplus`` so gradients reach every batch element:

        qLogEI ≈ log E_s[ τ_r·softplus( τ_m·logsumexp((f_s − best)/τ_m) / τ_r ) ]

    Module-level pure function (paired with per-call ``eps`` passed inside
    ``state``) ⇒ the engine's jit cache keys on shapes only.
    """
    gp, best, eps = state

    def one(xq):                                   # (q, D) -> ()
        mean, cov = predict_joint(gp, xq)
        Lc = jnp.linalg.cholesky(cov)
        samples = mean[None, :] + eps @ Lc.T       # (S, q)
        z = samples - best
        smax = tau_max * jax.scipy.special.logsumexp(z / tau_max, axis=-1)
        log_ei_s = jnp.log(tau_relu) + _log_softplus(smax / tau_relu)
        S = eps.shape[0]
        return jax.scipy.special.logsumexp(log_ei_s) - jnp.log(float(S))

    return jax.vmap(one)(xb)


def qlogei_state(gp: GPState, best, q: int, *, n_samples: int = 64,
                 seed: int = 0):
    """Build the ``(gp, best, eps)`` state tuple for ``qlogei_acq``."""
    eps = jax.random.normal(jax.random.PRNGKey(seed), (n_samples, q),
                            gp.y_train.dtype)
    return (gp, jnp.asarray(best, gp.y_train.dtype), eps)


def make_logei(gp: GPState, best: float) -> AcqBatched:
    """LogEI closure over a fitted GP (y standardized, maximization scale)."""
    best = jnp.asarray(best, gp.y_train.dtype)

    def acq(xb: Array) -> Array:
        mean, var = predict(gp, xb)
        return log_ei(mean, var, best)

    return acq


def make_ucb(gp: GPState, beta: float = 2.0) -> AcqBatched:
    def acq(xb: Array) -> Array:
        mean, var = predict(gp, xb)
        return ucb(mean, var, beta)

    return acq
