from repro.core.lbfgsb import (LbfgsbOptions, LbfgsbResult, lbfgsb_minimize,
                               bfgs_minimize, make_batched_value_and_grad,
                               inv_hessian_dense, two_loop_direction)
from repro.core.mso import (MsoOptions, MsoResult, maximize_acqf,
                            maximize_acqf_closure, STRATEGIES)
from repro.core.acquisition import (log_ei, log_h, ei, ucb, make_logei,
                                    make_ucb, logei_acq, ucb_acq)
