"""Flash attention (forward) as a Pallas TPU kernel.

The LM substrate's prefill hot spot: O(S²·H) attention FLOPs at 32k context.
Online-softmax streaming over KV tiles keeps the (S, S) score matrix out of
HBM entirely — VMEM holds one (BLOCK_Q, BLOCK_K) score tile plus the running
(BLOCK_Q, H) accumulator and max/sum statistics in scratch.

Supports causal masking and RecurrentGemma-style local windows (query i sees
keys in (i-window, i]).  Backward runs through XLA recompute (the dry-run
path uses the pure-XLA attention anyway; this kernel is the TPU serving /
prefill path, validated in interpret mode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, window: Optional[int],
                  sk: int, sq: int, block_q: int, block_k: int):
    """Grid = (q_tiles, k_tiles); the k axis is the streaming reduction."""
    qi = pl.program_id(0)
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)            # (BQ, H)
    k = k_ref[...].astype(jnp.float32)            # (BK, H)
    v = v_ref[...].astype(jnp.float32)            # (BK, H)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # absolute positions, suffix-aligned: query row r of block qi sits at
    # position (sk - sq) + qi*block_q + r — supports prefill-with-cache.
    iq = (qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
          + (sk - sq))
    ik = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = ik < sk                                # key padding
    if causal:
        mask &= ik <= iq
    if window is not None:
        mask &= ik > iq - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                # (BQ, 1)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] /
                      jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Single-head flash attention.  q: (Sq, H), k/v: (Sk, H) → (Sq, H).

    vmap over (batch, heads) for full layouts; H should be 128-aligned on
    real TPU (the LM substrate's head dims are).
    """
    sq, h = q.shape
    sk = k.shape[0]
    scale = float(h ** -0.5) if scale is None else float(scale)

    q_pad = (-sq) % block_q
    k_pad = (-sk) % block_k
    qp = jnp.pad(q, ((0, q_pad), (0, 0)))
    kp = jnp.pad(k, ((0, k_pad), (0, 0)))
    vp = jnp.pad(v, ((0, k_pad), (0, 0)))
    SQ, SK = qp.shape[0], kp.shape[0]
    grid = (SQ // block_q, SK // block_k)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        sk=sk, sq=sq, block_q=block_q, block_k=block_k)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, h), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, h), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, h), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, h), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((SQ, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, h), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:sq]


def flash_attention_bhsd(q, k, v, **kw):
    """(B, H, S, D) convenience layout: vmap over batch and heads."""
    fn = functools.partial(flash_attention, **kw)
    return jax.vmap(jax.vmap(fn))(q, k, v)
