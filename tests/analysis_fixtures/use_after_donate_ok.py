"""Fixture: donation used correctly — the donated name is rebound to
the call's result before any later read.  Zero ``use-after-donate``
findings."""
from repro.engine.cache import CountingJit


def _refit(gp_state, X):
    return gp_state


class Owner:
    def __init__(self):
        self._refit_jit = CountingJit(_refit, donate_argnums=(0,))

    def step(self, gp_state, X):
        gp_state = self._refit_jit(gp_state, X)
        return gp_state

    def step_fresh_name(self, gp_state, X):
        new_state = self._refit_jit(gp_state, X)
        return new_state
