"""Public op: Matérn-5/2 gram with backend dispatch.

``backend="pallas"`` targets TPU (or ``interpret=True`` for CPU validation);
``backend="xla"`` is the pure-jnp path used by the CPU BO benchmarks.
"""
from __future__ import annotations

import jax

from repro.kernels.matern.kernel import matern52_gram
from repro.kernels.matern.ref import matern52_gram_ref


def matern52_cross(x1: jax.Array, x2: jax.Array, inv_lengthscale: jax.Array,
                   amplitude: jax.Array, *, backend: str = "xla",
                   interpret: bool = False) -> jax.Array:
    if backend == "pallas":
        return matern52_gram(x1, x2, inv_lengthscale, amplitude,
                             interpret=interpret)
    if backend == "xla":
        return matern52_gram_ref(x1, x2, inv_lengthscale, amplitude)
    raise ValueError(f"unknown backend {backend!r}")
