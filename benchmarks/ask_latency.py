"""Per-trial ask() latency: fused one-program suggest vs the host pipeline.

Runs full GPSampler BO loops (strategy=dbe_vec) and times every `ask()`:

* **unfused** (PR 1 host pipeline): from-scratch multi-start MAP `fit_gp`
  + host restart sampling + `run_lockstep` — per-trial O(n³) refit cost;
* **fused** (`engine/ask.py`): one compiled program per GP size bucket,
  rank-one incremental refits between `refit_interval`-spaced full MAP
  refits — steady-state trials skip both the O(n³) refactorization and
  the MAP optimization entirely.

Emits BENCH_ask.json: per-trial ask-latency trajectories, per-trial
refit kinds, steady-state medians, and exact compile counts (must stay
O(#size-buckets), not O(trials) — asserted with --check-compiles).

Steady-state definition (apples-to-apples): suggest trials that pay no
XLA trace and no bucket migration — for the fused run additionally the
trials that take the incremental (O(n²)) program, which is the
steady-state the fused pipeline is designed around.

--trace enables the obs span tracer for the whole run (off by default —
the obs contract): per-phase breakdowns land in the summary block, the
full Chrome-trace JSON in --trace-out, and --check-compiles still
asserts the O(#buckets) compile economy WITH tracing on (instrumentation
must never add traces).  --debug-nans arms the runtime FiniteGuard on
the two fused AskEngine programs.

Usage:
  python benchmarks/ask_latency.py [--tiny] [--trials N]
      [--backends xla pallas_interpret ...] [--check-compiles]
      [--trace] [--trace-out BENCH_ask_trace.json] [--debug-nans]
      [--out BENCH_ask.json]
"""
import argparse
import json
import platform
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                     # noqa: E402

from repro.analysis.runtime import install_nan_guard, nan_guard_stats  # noqa: E402
from repro.bo.objectives import make_objective         # noqa: E402
from repro.bo.sampler import GPSampler                 # noqa: E402
from repro.bo.space import BoxSpace                    # noqa: E402
from repro.core.mso import MsoOptions                  # noqa: E402
from repro.gp.fit import pad_bucket_for                # noqa: E402
from repro.obs import export as obs_export             # noqa: E402
from repro.obs import trace as obs_trace               # noqa: E402


def run_bo(*, fused: bool, backend: str, trials: int, D: int, B: int,
           pad: int, refit_interval: int, n_startup: int, seed: int = 0,
           debug_nans: bool = False):
    obj = make_objective("sphere", D, seed=seed)
    space = BoxSpace.cube(D, *obj.bounds)
    s = GPSampler(space, strategy="dbe_vec", seed=seed,
                  n_startup_trials=n_startup, n_restarts=B,
                  pad_multiple=pad, posterior_backend=backend,
                  fused=fused, refit_interval=refit_interval,
                  mso_options=MsoOptions())
    ask_ms, kinds, buckets = [], [], []
    prev_bucket = 0
    for i in range(trials):
        if debug_nans and fused and s._ask is not None:
            install_nan_guard(s._ask)   # idempotent; engine is lazy-built
        n_done = sum(t.state == "complete" for t in s.trials)
        suggest = n_done >= n_startup
        bucket = pad_bucket_for(n_done, pad) if suggest else 0
        t0 = time.perf_counter()
        t = s.ask()
        ask_ms.append(1e3 * (time.perf_counter() - t0))
        if not suggest:
            kinds.append("startup")
        elif fused:
            kinds.append(s.last_ask_info.kind)
        else:
            kinds.append("host_fit" if bucket == prev_bucket
                         else "host_fit_newbucket")
        if suggest:
            buckets.append(bucket)
            prev_bucket = bucket
        s.tell(t.trial_id, obj(t.x))
    return s, ask_ms, kinds, sorted(set(buckets))


def steady_mask(kinds, fused: bool):
    """Steady-state trials: no trace, no bucket migration; for fused runs
    the incremental-program trials (its designed steady state)."""
    if fused:
        return [k == "incremental" for k in kinds]
    # host pipeline: same-bucket fit trials; bucket-migration trials pay
    # the fresh per-bucket traces and are excluded on both sides
    return [k == "host_fit" for k in kinds]


def bench_backend(backend: str, args) -> list:
    rows = []
    for fused in (False, True):
        s, ask_ms, kinds, buckets = run_bo(
            fused=fused, backend=backend, trials=args.trials, D=args.D,
            B=args.B, pad=args.pad, refit_interval=args.refit_interval,
            n_startup=args.n_startup, debug_nans=args.debug_nans)
        suggest_ms = [m for m, k in zip(ask_ms, kinds) if k != "startup"]
        sm = [m for m, keep in zip(ask_ms, steady_mask(kinds, fused))
              if keep]
        engine = s.stats.engine or {}
        row = {
            "backend": backend, "fused": fused, "trials": args.trials,
            "n_startup": args.n_startup, "D": args.D, "B": args.B,
            "pad": args.pad, "refit_interval": args.refit_interval,
            "gp_buckets": buckets,
            "ask_ms": [round(m, 3) for m in ask_ms],
            "kinds": kinds,
            "median_suggest_ms": float(np.median(suggest_ms)),
            "steady_ms": float(np.median(sm)) if sm else None,
            "n_steady_trials": len(sm),
            "best_y": s.best().y,
            "retrace_causes": (engine.get("retraces") or {}).get("causes"),
        }
        if fused:
            row["ask_stats"] = {k: engine.get(k) for k in
                                ("n_full_refits", "n_incremental",
                                 "n_fallbacks", "n_full_compiles",
                                 "n_incr_compiles", "n_ask_compiles")}
            if args.debug_nans and s._ask is not None:
                row["nan_guard"] = nan_guard_stats(s._ask)
        else:
            row["engine_compiles"] = engine.get("n_compiles")
            row["eval_rounds_total"] = engine.get("n_rounds")
            row["points_evaluated"] = engine.get("n_points")
        rows.append(row)
        steady = (f"{row['steady_ms']:.1f}ms" if row["steady_ms"]
                  is not None else "n/a")
        print(f"ask,{backend},fused={fused},"
              f"median={row['median_suggest_ms']:.1f}ms,"
              f"steady={steady},"
              f"buckets={len(buckets)}", flush=True)

    unf, fus = rows
    # too few trials for a steady state (e.g. --trials barely past
    # startup) ⇒ no steady speedup to report
    have_steady = (unf["steady_ms"] is not None
                   and fus["steady_ms"] is not None)
    speed = {
        "backend": backend,
        "speedup_steady": (unf["steady_ms"] / fus["steady_ms"]
                           if have_steady else None),
        "speedup_median": (unf["median_suggest_ms"]
                           / fus["median_suggest_ms"]),
    }
    if have_steady:
        print(f"ask,{backend},steady speedup "
              f"{speed['speedup_steady']:.2f}x, median speedup "
              f"{speed['speedup_median']:.2f}x", flush=True)
    else:
        print(f"ask,{backend},median speedup "
              f"{speed['speedup_median']:.2f}x (no steady-state trials)",
              flush=True)

    if args.check_compiles:
        n_buckets = len(fus["gp_buckets"])
        compiles = fus["ask_stats"]["n_ask_compiles"]
        n_suggests = args.trials - args.n_startup
        assert compiles <= 2 * n_buckets, \
            f"fused ask compiled {compiles}x for {n_buckets} buckets " \
            f"(must be <= 2/bucket, not O(trials)={n_suggests}); " \
            f"retrace causes: {fus['retrace_causes']}"
        # O(trials) sanity only meaningful once suggests outnumber the
        # per-bucket trace budget
        assert n_suggests <= 2 * n_buckets or compiles < n_suggests, \
            f"fused ask compiles {compiles} not < suggests {n_suggests}"
        print(f"ask,{backend},compile check OK "
              f"({compiles} traces / {n_buckets} buckets / "
              f"{n_suggests} suggests)", flush=True)
    return rows + [speed]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: few trials, small GP buckets")
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--backends", nargs="+", default=None,
                    choices=("xla", "pallas", "pallas_interpret"))
    ap.add_argument("--check-compiles", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="enable the obs span tracer (off by default); "
                    "adds a per-phase breakdown to the summary and "
                    "writes the Chrome-trace JSON to --trace-out")
    ap.add_argument("--trace-out", default="BENCH_ask_trace.json")
    ap.add_argument("--debug-nans", action="store_true",
                    help="wrap the two fused AskEngine programs in a "
                    "finite-guard: every float leaf entering/leaving "
                    "them is checked (one host sync per call)")
    ap.add_argument("--out", default="BENCH_ask.json")
    args = ap.parse_args(argv)

    if args.tiny:
        args.trials = args.trials or 26
        args.D, args.B, args.pad = 3, 6, 8
        args.refit_interval, args.n_startup = 4, 6
        args.backends = args.backends or ["xla"]
    else:
        args.trials = args.trials or 150
        args.D, args.B, args.pad = 6, 10, 32
        args.refit_interval, args.n_startup = 8, 10
        args.backends = args.backends or ["xla", "pallas_interpret"]

    if args.trace:
        obs_trace.enable()

    out = []
    for backend in args.backends:
        out.extend(bench_backend(backend, args))

    # headline scalars, one per configuration (the speed rows carry no
    # "fused" key; per-run rows do)
    summary = {}
    if args.trace:
        events = obs_trace.get().events()
        summary["phase_breakdown"] = obs_export.phase_breakdown(events)
        obs_export.write_chrome_trace(
            args.trace_out, events, process_name="ask_latency",
            meta={"bench": "ask_latency"})
        print(f"wrote {args.trace_out} ({len(events)} trace events)")
    for r in out:
        if "fused" in r:
            tag = f"{r['backend']}_{'fused' if r['fused'] else 'unfused'}"
            summary[f"{tag}_median_suggest_ms"] = r["median_suggest_ms"]
            if r["steady_ms"] is not None:
                summary[f"{tag}_steady_ms"] = r["steady_ms"]
            if r["retrace_causes"] is not None:
                summary[f"{tag}_retrace_causes"] = r["retrace_causes"]
        else:
            summary[f"{r['backend']}_speedup_median"] = r["speedup_median"]
            if r["speedup_steady"] is not None:
                summary[f"{r['backend']}_speedup_steady"] = \
                    r["speedup_steady"]

    record = {
        "bench": "ask_latency",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "device": jax.devices()[0].device_kind,
        "jax_backend": jax.default_backend(),
        "python": platform.python_version(),
        "mode": "tiny" if args.tiny else "default",
        "summary": summary,
        "rows": out,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out} ({len(out)} rows)")
    return out


if __name__ == "__main__":
    main()
