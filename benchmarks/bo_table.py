"""Paper Tables 1 & 2 — end-to-end BO benchmark.

BO with GPSampler (Matérn-5/2 + LogEI), L-BFGS-B m=10, B=10 restarts,
termination 200 iters or ||∇α||_inf ≤ 1e-2, objectives Sphere / Attractive
Sector / Step Ellipsoidal / Rastrigin at D ∈ {5,10,20,40}, strategies
SEQ. OPT. / C-BE / D-BE (+ our D-BE-vectorized).

Reported per (objective, D, strategy): median best-value, median BO
wall-clock, median acqf wall-clock, median per-trial L-BFGS-B iterations —
the paper's three columns plus the acqf-only time.

Paper scale (--full): 300 trials × 20 seeds.  CPU-reduced default:
60 trials × 3 seeds × D ∈ {5,10} × {rastrigin, sphere}.
"""
import jax

jax.config.update("jax_enable_x64", True)

import time                        # noqa: E402

import numpy as np                 # noqa: E402

from repro.bo.objectives import make_objective      # noqa: E402
from repro.bo.sampler import GPSampler               # noqa: E402
from repro.bo.space import BoxSpace                  # noqa: E402
from repro.core.mso import MsoOptions                # noqa: E402


def run_one(objective: str, D: int, strategy: str, seed: int,
            n_trials: int, B: int = 10):
    obj = make_objective(objective, D, seed=1)   # same instance ∀ seeds
    space = BoxSpace.cube(D, *obj.bounds)
    sampler = GPSampler(
        space, strategy=strategy, seed=seed, n_startup_trials=10,
        n_restarts=B,
        mso_options=MsoOptions(m=10, maxiter=200, pgtol=1e-2))
    t0 = time.perf_counter()
    best = sampler.optimize(obj, n_trials)
    wall = time.perf_counter() - t0
    return {
        "objective": objective, "D": D, "strategy": strategy, "seed": seed,
        "best_value": best.y,
        "runtime_s": wall,
        "acqf_s": sampler.stats.acqf_time,
        "fit_s": sampler.stats.fit_time,
        "med_iters": float(np.median(sampler.stats.acqf_iters))
        if sampler.stats.acqf_iters else 0.0,
    }


def run_table(objectives, dims, strategies, seeds, n_trials):
    rows = []
    for objective in objectives:
        for D in dims:
            base = None
            for strategy in strategies:
                per_seed = [run_one(objective, D, strategy, s, n_trials)
                            for s in range(seeds)]
                med = {k: float(np.median([r[k] for r in per_seed]))
                       for k in ("best_value", "runtime_s", "acqf_s",
                                 "fit_s", "med_iters")}
                row = {"objective": objective, "D": D,
                       "strategy": strategy, "seeds": seeds,
                       "trials": n_trials, **med}
                if strategy == "seq":
                    base = med
                if base:
                    row["acqf_speedup_vs_seq"] = \
                        base["acqf_s"] / max(med["acqf_s"], 1e-12)
                rows.append(row)
                print(f"bo,{objective},D={D},{strategy},"
                      f"best={med['best_value']:.4g},"
                      f"runtime={med['runtime_s']:.1f}s,"
                      f"acqf={med['acqf_s']:.1f}s,"
                      f"iters={med['med_iters']:.1f}", flush=True)
    return rows


def main(full=False):
    if full:
        return run_table(
            ("sphere", "attractive_sector", "step_ellipsoidal",
             "rastrigin"),
            (5, 10, 20, 40), ("seq", "cbe", "dbe", "dbe_vec"), 20, 300)
    return run_table(("rastrigin", "sphere"), (5, 10),
                     ("seq", "cbe", "dbe", "dbe_vec"), 3, 60)


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
