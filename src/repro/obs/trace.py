"""Host-side span tracer: the flight recorder behind ``python -m repro.obs``.

One process-global :class:`Tracer` holds a bounded ring of finished
events in Chrome-trace form (``ph="X"`` complete spans with microsecond
``ts``/``dur``, ``ph="i"`` instants).  Instrumentation sites call the
module-level :func:`span` / :func:`instant` helpers, which are a single
``None``-check when tracing is off — the off-by-default contract in the
ROADMAP's obs invariant.  Everything here is host state: nothing in this
module may be read inside a traced closure (the ``host-leak-into-trace``
rule), and enabling the tracer must never change what XLA compiles
(asserted by every ``--check-compiles`` benchmark path with ``--trace``).

Device programs are timed through :class:`ProgramTimer`, which follows
the ``analysis/runtime.py::FiniteGuard`` pattern: it re-wraps an already
constructed ``CountingJit`` attribute, passes every other attribute
through (``n_compiles``, ``retrace_summary`` …), and — only while the
tracer is enabled — blocks until the program's outputs are ready so the
span measures device completion, not dispatch.  When tracing is off it
adds one attribute load and one ``None``-check per call.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

DEFAULT_CAPACITY = 65536


class Tracer:
    """Bounded, thread-safe ring of finished Chrome-trace events.

    Timestamps are microseconds relative to tracer creation
    (``perf_counter`` based), which is what Chrome-trace ``ts`` expects.
    When the ring is full the oldest events fall off (``n_dropped``
    counts them) — a flight recorder keeps the recent past, it never
    grows without bound.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.n_recorded = 0
        self.n_dropped = 0

    def now_us(self) -> float:
        return 1e6 * (time.perf_counter() - self._t0)

    def _push(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.n_dropped += 1
            self._events.append(ev)
            self.n_recorded += 1

    def record_span(self, name: str, ts_us: float, dur_us: float,
                    **attrs: Any) -> None:
        ev: Dict[str, Any] = {
            "name": name, "ph": "X",
            "ts": round(ts_us, 3), "dur": round(max(dur_us, 0.0), 3),
            "pid": os.getpid(), "tid": threading.get_ident()}
        if attrs:
            ev["args"] = attrs
        self._push(ev)

    def record_instant(self, name: str, **attrs: Any) -> None:
        ev: Dict[str, Any] = {
            "name": name, "ph": "i", "ts": round(self.now_us(), 3),
            "s": "t", "pid": os.getpid(), "tid": threading.get_ident()}
        if attrs:
            ev["args"] = attrs
        self._push(ev)

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of the ring, oldest first."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.n_recorded = 0
            self.n_dropped = 0


# The process-global tracer. ``None`` means disabled: span()/instant()
# reduce to one module-global load and a None-check, so instrumented hot
# paths cost nothing measurable with tracing off (see the ``overhead``
# CLI subcommand, which enforces a per-call budget in CI).
_TRACER: Optional[Tracer] = None


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Install (and return) a fresh process-global tracer."""
    global _TRACER
    _TRACER = Tracer(capacity)
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def enabled() -> bool:
    return _TRACER is not None


def get() -> Optional[Tracer]:
    return _TRACER


@contextmanager
def span(name: str, **attrs: Any) -> Iterator[None]:
    """Time a host-side region as a complete ("X") event; no-op when
    tracing is disabled.  Attributes land in the event's ``args``."""
    tr = _TRACER
    if tr is None:
        yield
        return
    t0 = tr.now_us()
    try:
        yield
    finally:
        tr.record_span(name, t0, tr.now_us() - t0, **attrs)


def instant(name: str, **attrs: Any) -> None:
    """Record a point event ("i"); no-op when tracing is disabled."""
    tr = _TRACER
    if tr is not None:
        tr.record_instant(name, **attrs)


class ProgramTimer:
    """Wrap a ``CountingJit``-like program with device-completion timing.

    Installed *after* the ``CountingJit`` assignment (the construction
    call site stays intact for the static analyzer's jit registry).
    With the tracer enabled, each call records a span whose duration
    runs to ``jax.block_until_ready`` on the outputs and notes whether
    the call traced (``compiled``) via the wrapped counter.  Disabled:
    straight passthrough.  Attribute access forwards to the inner
    program, and stacking under :class:`~repro.analysis.runtime.
    FiniteGuard` (``--debug-nans``) keeps working in either order.
    """

    def __init__(self, inner, name: str):
        self._inner = inner
        self._name = name

    def __call__(self, *args: Any, **kwargs: Any):
        tr = _TRACER
        if tr is None:
            return self._inner(*args, **kwargs)
        import jax
        c0 = getattr(self._inner, "n_compiles", 0)
        t0 = tr.now_us()
        out = self._inner(*args, **kwargs)
        out = jax.block_until_ready(out)
        tr.record_span(self._name, t0, tr.now_us() - t0,
                       compiled=getattr(self._inner, "n_compiles", 0) > c0)
        return out

    def __getattr__(self, name: str):
        return getattr(self._inner, name)
