"""Compile-aware jit wrapper — the evaluation plane's cache primitive.

``CountingJit`` wraps a function in ``jax.jit`` with a side-effecting
trace counter: the increment executes at trace time only, so the counter
ticks exactly once per compiled executable and never on cache hits.  Both
the acquisition engine and the serving engine build their compiled planes
from this, which is what makes "compiles per run" a first-class, testable
metric (the ROADMAP's compilation-discipline requirement).

Mesh-sharded callers (the fleet ask plane) pass ``in_shardings``: every
call then keys the jit cache on the (mesh, PartitionSpec) pair baked in
here — never on whichever device a host-built input happened to land on,
and never on which slots are live.  That is what keeps fleet compile
counts O(#buckets) and independent of the mesh's device count: a block's
programs are traced once per (bucket, slots) shape per mesh, no matter
how studies move across devices between calls.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax


class CountingJit:
    """``jax.jit`` with an exact retrace/compile counter."""

    def __init__(self, fn: Callable, *,
                 static_argnums: Sequence[int] = (),
                 donate_argnums: Sequence[int] = (),
                 in_shardings: Optional[Any] = None,
                 out_shardings: Optional[Any] = None):
        self.n_compiles = 0

        def counted(*args, **kwargs):
            self.n_compiles += 1          # trace-time side effect
            return fn(*args, **kwargs)

        counted.__name__ = getattr(fn, "__name__", "counted")
        # donation lets steady-state callers (the fused ask path) reuse
        # their O(n²) GP buffers in place; XLA ignores it on CPU, so gate
        # there to avoid per-call "donated buffer unused" warnings
        if jax.default_backend() == "cpu":
            donate_argnums = ()
        kw: dict = {}
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            kw["out_shardings"] = out_shardings
        self._jit = jax.jit(counted,
                            static_argnums=tuple(static_argnums) or None,
                            donate_argnums=tuple(donate_argnums) or None,
                            **kw)

    def __call__(self, *args: Any, **kwargs: Any):
        return self._jit(*args, **kwargs)
