"""Benchmark entry point — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full]``

Prints ``name,us_per_call,derived`` CSV-style lines per section (reduced
CPU-scale settings by default; --full reproduces the paper's scale).
"""
import argparse
import sys
import time


def _section(title):
    print(f"\n# === {title} ===", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-bo", action="store_true",
                    help="skip the end-to-end BO table (slowest section)")
    args, _ = ap.parse_known_args()

    import jax
    jax.config.update("jax_enable_x64", True)

    t0 = time.time()

    _section("Fig 1/3/4: off-diagonal artifacts (e_rel, offdiag mass)")
    from benchmarks import offdiag
    offdiag.main(full=args.full)

    _section("Fig 2/5: C-BE convergence slowdown vs B")
    from benchmarks import convergence
    convergence.main(full=args.full)

    _section("§5 cost model + wall-clock: MSO micro-benchmark")
    from benchmarks import mso_walltime
    mso_walltime.main(full=args.full)

    _section("kernels: Pallas interpret-mode correctness + XLA timing")
    from benchmarks import kernels
    kernels.main(full=args.full)

    if not args.skip_bo:
        _section("Table 1/2: end-to-end BO (reduced scale by default)")
        from benchmarks import bo_table
        bo_table.main(full=args.full)

    _section("roofline (from results/dryrun, if present)")
    import glob
    if glob.glob("results/dryrun/*.json"):
        from benchmarks import roofline
        sys.argv = ["roofline"]
        roofline.main()
    else:
        print("roofline,skipped,no results/dryrun jsons (run "
              "repro.launch.dryrun --sweep first)")

    print(f"\n# total benchmark wall time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
