"""Posterior backends for the evaluation engine.

The dominant per-round cost of MSO is the batched GP posterior (paper §4:
one (k, n) cross-gram + triangular solves per evaluation round).  This
module routes that hot path:

* ``"xla"``     — the classic Cholesky-solve ``gp.gpr.predict`` (exact,
                  differentiable, runs anywhere);
* ``"pallas"``  — the fused cross-gram + mean/variance Pallas kernel
                  (``kernels.matern``): the (k, n) slab never round-trips
                  through HBM; gradients route through a custom VJP;
* ``"pallas_interpret"`` — same kernel in interpreter mode (CPU
                  validation / CI);
* ``"auto"``    — pallas on TPU, xla elsewhere.

The fused path needs ``GPState.kinv`` (see ``gp.gpr.with_kinv``); states
without it fall back to the Cholesky path regardless of backend.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.acquisition import log_ei
from repro.gp.gpr import GPState, predict
from repro.kernels.matern.ops import matern52_posterior_op

Array = jax.Array

BACKENDS = ("auto", "xla", "pallas", "pallas_interpret")


def resolve_backend(backend: str = "auto") -> str:
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def posterior(gp: GPState, xb: Array, *, backend: str = "auto"
              ) -> Tuple[Array, Array]:
    """Batched posterior ((k,) mean, (k,) var) via the chosen backend."""
    backend = resolve_backend(backend)
    if (backend.startswith("pallas") and gp.kernel == "matern52"
            and gp.kinv is not None):
        inv_ls = jnp.exp(-gp.params.log_lengthscale)
        return matern52_posterior_op(
            xb, gp.x_train, gp.alpha, gp.kinv, inv_ls,
            gp.params.amplitude, backend="pallas",
            interpret=(backend == "pallas_interpret"))
    return predict(gp, xb)


# one acq function object per backend: the engine's jit caches key on
# function identity, so these must be stable across calls
_LOGEI_CACHE: Dict[str, Callable] = {}


def fused_logei_acq(backend: str = "auto") -> Callable:
    """State-form LogEI (``state = (GPState, best)``) over the chosen
    posterior backend — drop-in for ``core.acquisition.logei_acq``."""
    backend = resolve_backend(backend)
    fn = _LOGEI_CACHE.get(backend)
    if fn is None:
        def acq(state, xb, _backend=backend):
            gp, best = state
            mean, var = posterior(gp, xb, backend=_backend)
            return log_ei(mean, var, best)
        acq.__name__ = f"logei_acq_{backend}"
        _LOGEI_CACHE[backend] = fn = acq
    return fn
