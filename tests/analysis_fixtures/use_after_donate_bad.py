"""Fixture: a donated buffer read after the donating call without a
rebind — must trip ``use-after-donate``."""
from repro.engine.cache import CountingJit


def _refit(gp_state, X):
    return gp_state


class Owner:
    def __init__(self):
        self._refit_jit = CountingJit(_refit, donate_argnums=(0,))

    def step(self, gp_state, X):
        out = self._refit_jit(gp_state, X)
        # BAD: gp_state's buffer was donated to the call above; XLA may
        # already have reused it.
        stale = gp_state
        return out, stale
