"""Evaluation planning: shape buckets and pad-or-shrink scheduling.

XLA compiles one executable per input shape, but the paper's D-BE batch
*shrinks* as restarts converge (§4 "the batch shrinks progressively").
Naively feeding the live active-set size to jit would compile once per
distinct size — up to B executables per strategy.  ``EvalPlan`` resolves the
tension with a geometric bucket ladder: an active set of k points is padded
up to the smallest bucket ≥ k, so the whole shrinking schedule runs through
at most ``log2(B)+1`` compiled shapes while wasting at most ~2× padded rows
in the worst round (vs B× for pad-to-max on the tail of the schedule).

The same plan object also describes q-batch (joint-candidate) layouts: an
evaluation batch is (k, q, D) with q=1 meaning classic single-point
acquisition (shape (k, D), no q axis materialized — backward compatible).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


def bucket_ladder(max_batch: int, min_bucket: int = 1) -> Tuple[int, ...]:
    """Geometric (power-of-two) bucket sizes covering [1, max_batch].

    Always contains ``max_batch`` itself so the opening full-batch rounds
    never pad.  E.g. max_batch=10 → (1, 2, 4, 8, 10).
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    ladder = []
    b = max(min_bucket, 1)
    while b < max_batch:
        ladder.append(b)
        b *= 2
    ladder.append(max_batch)
    return tuple(ladder)


@dataclass(frozen=True)
class EvalPlan:
    """Static description of one acquisition-evaluation workload.

    Hashable and immutable: used as (part of) the engine's jit-cache key.

    Attributes:
      max_batch: B, the number of restarts (upper bound on active set).
      dim: D, the search-space dimension.
      q: joint-candidate count (1 = classic single-point acquisition).
      buckets: allowed padded batch sizes, ascending; every evaluation is
        padded up to the smallest bucket that fits its active set.
    """
    max_batch: int
    dim: int
    q: int = 1
    buckets: Tuple[int, ...] = ()

    @classmethod
    def for_batch(cls, max_batch: int, dim: int, *, q: int = 1,
                  bucketed: bool = True) -> "EvalPlan":
        """Standard plan: geometric ladder, or fixed pad-to-max when
        ``bucketed=False`` (the seed repo's behaviour, kept measurable)."""
        buckets = bucket_ladder(max_batch) if bucketed else (max_batch,)
        return cls(max_batch=max_batch, dim=dim, q=q, buckets=buckets)

    def __post_init__(self):
        if self.q < 1:
            raise ValueError(f"q must be >= 1, got {self.q}")
        if not self.buckets:
            object.__setattr__(self, "buckets", (self.max_batch,))
        if self.buckets[-1] < self.max_batch:
            raise ValueError(
                f"largest bucket {self.buckets[-1]} cannot hold "
                f"max_batch={self.max_batch}")

    def bucket_for(self, k: int) -> int:
        """Smallest bucket that holds an active set of ``k`` points."""
        if k < 1 or k > self.max_batch:
            raise ValueError(f"active-set size {k} outside [1, "
                             f"{self.max_batch}]")
        for b in self.buckets:
            if b >= k:
                return b
        return self.buckets[-1]

    @property
    def point_shape(self) -> Tuple[int, ...]:
        """Trailing shape of one candidate: (D,) or (q, D)."""
        return (self.dim,) if self.q == 1 else (self.q, self.dim)

    @property
    def flat_dim(self) -> int:
        """Dimension each QN worker optimizes over (q·D for joint mode)."""
        return self.q * self.dim
