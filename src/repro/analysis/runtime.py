"""Runtime sanitizers: the opt-in fleet NaN guard (``--debug-nans``).

The static ``nan-hazard`` rule proves no *syntactic* path feeds a
non-finite value into a shared carry; this guard proves the actual
``_FAR`` benign-row invariant at runtime — every float leaf entering or
leaving the three fleet block programs (full refit, incremental refit,
MSO tail) is finite, idle and quarantined rows included.  It costs one
host sync per program call, so it is strictly opt-in (chaos benches,
debugging), never the hot path.
"""
from __future__ import annotations

from typing import Any, Iterable, Tuple

import jax
import jax.numpy as jnp


class NonFiniteError(AssertionError):
    """A float leaf crossing a guarded program boundary was NaN/Inf."""


def _first_nonfinite(tree: Any) -> Tuple[str, Any]:
    """(path, leaf) of the first non-finite float leaf, or ("", None)."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    for path, leaf in leaves:
        dt = getattr(leaf, "dtype", None)
        if dt is None or not jnp.issubdtype(dt, jnp.floating):
            continue
        if not bool(jnp.all(jnp.isfinite(leaf))):
            return jax.tree_util.keystr(path), leaf
    return "", None


class FiniteGuard:
    """Wrap a CountingJit-like callable with finite-checks on every
    float input and output leaf.  All other attributes (``n_compiles``,
    ``retrace_summary`` …) pass through, so engine snapshots keep
    working on the guarded program."""

    def __init__(self, inner, label: str):
        self._inner = inner
        self._label = label
        self.n_guard_checks = 0

    def _check(self, tree: Any, direction: str) -> None:
        path, leaf = _first_nonfinite(tree)
        if leaf is not None:
            raise NonFiniteError(
                f"non-finite value in {direction} of fleet program "
                f"'{self._label}' at leaf {path or '<root>'} "
                f"(shape {getattr(leaf, 'shape', '?')}): the _FAR "
                f"benign-row invariant is violated — an idle/quarantined "
                f"slot leaked NaN/Inf into the shared carry")

    def __call__(self, *args: Any, **kwargs: Any):
        self.n_guard_checks += 1
        self._check((args, kwargs), "inputs")
        out = self._inner(*args, **kwargs)
        self._check(out, "outputs")
        return out

    def __getattr__(self, name: str):
        return getattr(self._inner, name)


_FLEET_PROGRAMS = ("_full_jit", "_incr_jit", "_mso_jit")


def install_nan_guard(fleet_engine) -> Iterable[FiniteGuard]:
    """Wrap the three fleet block programs in place; returns the guards
    (idempotent: re-installing over an existing guard is a no-op)."""
    guards = []
    for attr in _FLEET_PROGRAMS:
        prog = getattr(fleet_engine, attr)
        if isinstance(prog, FiniteGuard):
            guards.append(prog)
            continue
        g = FiniteGuard(prog, attr.strip("_").replace("_jit", ""))
        setattr(fleet_engine, attr, g)
        guards.append(g)
    return guards


def nan_guard_stats(fleet_engine) -> dict:
    """``{"installed": bool, "n_guard_checks": int}`` for summaries."""
    progs = [getattr(fleet_engine, a, None) for a in _FLEET_PROGRAMS]
    installed = all(isinstance(p, FiniteGuard) for p in progs)
    return {"installed": installed,
            "n_guard_checks": sum(p.n_guard_checks for p in progs
                                  if isinstance(p, FiniteGuard))}
