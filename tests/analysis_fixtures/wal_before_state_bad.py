"""Fixture: journaled state mutated BEFORE the journal append.

Every function here must trip ``wal-before-state``.  Parsed by the
linter, never imported.
"""


class Engine:
    def __init__(self):
        self.journal = None
        self.studies = {}
        self.queue = []

    def _journal(self, kind, **fields):
        if self.journal is not None:
            self.journal.append(kind, **fields)

    def evict_then_journal(self, st):
        # BAD: destructive pop happens before the WAL record exists —
        # a crash between the two lines loses the study silently.
        self.studies.pop(st.sid)
        self._journal("evict", study=st.sid)

    def flag_then_journal(self, st, reason):
        # BAD: scalar lifecycle attr mutated pre-append.
        st.shed = reason
        self._journal("shed", study=st.sid, reason=reason)

    def install_then_journal(self, st, slot):
        # BAD: slot table grows before the admit record.
        self.studies[slot] = st
        self._journal("admit", study=st.sid, slot=slot)
