"""BO-as-a-service under open-loop Poisson load: latency, goodput, QoS.

Drives :class:`repro.serve.bo_service.BOService` the way the north-star
workload does (ROADMAP item 3): named tenants with heterogeneous weights
and arrival rates submit ask requests on an *open-loop* schedule —
arrival times are drawn up front from seeded per-tenant Poisson
processes, and a request is submitted when its arrival time comes due
whether or not the service has caught up (so backlog builds honestly
under overload instead of the load adapting to the server).  Completed
asks are told back immediately with a synthetic objective, closing the
BO loop.

Tenant mixes (each is one benchmark configuration, >=1 row per tenant):

* **uniform** — three equal-weight tenants at the same moderate rate:
  the baseline fairness row (per-tenant p50/p99 should be close).
* **skew** — a heavy low-priority tenant (2 studies, burst arrivals, no
  deadline) floods the service while a light high-weight tenant submits
  sparse deadline-carrying requests.  The QoS claim under test: DRR
  isolates the light tenant — its p99 stays bounded (and below the
  flooding tenant's) and it sheds nothing, no matter the backlog next
  door.  --check-compiles asserts exactly that (zero cross-tenant
  starvation), plus the fleet compile-economy budget (<=3 traces per
  (bucket, slots) shape — tenancy, deadlines, and overload handling are
  host-side and add no programs).

--chaos adds a kill-and-recover row: the same skewed workload runs
journaled with fault injection — deterministic latency injection (slow
full refits + slow tells) plus an injected process kill ~60% through
the expected journal stream.  :meth:`BOService.recover` rebuilds the
service, re-tells the suggests that were in flight at the kill, serves
the restored pending queue, then finishes the arrival schedule.
Reported: goodput over the whole incident (must stay > 0), the pre-
crash / post-recovery split, deadline misses, sheds, and replay cost —
field-compatible with ``benchmarks/fleet_throughput.py --chaos`` so the
two BENCH files diff against each other.

Emits BENCH_serve.json (append-only row array + a ``summary`` dict of
headline scalars, same contract as the other BENCH files).

--trace enables the obs span tracer for the whole run (off by default):
DRR-round/dispatch spans and QoS instants (sheds, rung changes,
degrades) land in --trace-out as Chrome-trace JSON, and the summary
gains a per-phase breakdown.  --check-compiles still holds WITH tracing
on — instrumentation must never add programs.

Usage:
  python benchmarks/bo_serve.py [--tiny] [--requests N] [--seed K]
      [--chaos] [--check-compiles] [--trace]
      [--trace-out BENCH_serve_trace.json] [--out BENCH_serve.json]
"""
import argparse
import json
import os
import platform
import shutil
import sys
import tempfile
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                     # noqa: E402

from repro.analysis.runtime import (install_nan_guard,  # noqa: E402
                                    nan_guard_stats)
from repro.bo.objectives import make_objective         # noqa: E402
from repro.bo.sampler import FleetSampler              # noqa: E402
from repro.bo.space import BoxSpace                    # noqa: E402
from repro.core.mso import MsoOptions                  # noqa: E402
from repro.engine import FleetFullError                # noqa: E402
from repro.obs import export as obs_export             # noqa: E402
from repro.obs import trace as obs_trace               # noqa: E402
from repro.serve.bo_service import (BOService,         # noqa: E402
                                    TenantConfig)

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.abspath(__file__)), "..", "tests"))


def _tenant_specs(args):
    """mix -> [(name, weight, n_studies, rate_hz, deadline_s, n_reqs)]"""
    n = args.requests
    return {
        "uniform": [
            ("t0", 1.0, 1, args.rate_mid, None, n),
            ("t1", 1.0, 1, args.rate_mid, None, n),
            ("t2", 1.0, 1, args.rate_mid, None, n),
        ],
        "skew": [
            ("heavy", 1.0, 2, args.rate_burst, None, 2 * n),
            ("light", 4.0, 1, args.rate_low, args.light_deadline, n),
        ],
    }


def _arrivals(specs, seed):
    """Open-loop Poisson schedule: [(t_arr, tenant, study, deadline)],
    sorted by arrival time, drawn up front from a seeded generator."""
    rng = np.random.default_rng(seed)
    events = []
    study_base = 0
    for name, _w, n_studies, rate, deadline, n_reqs in specs:
        t = 0.0
        for k in range(n_reqs):
            t += float(rng.exponential(1.0 / rate))
            study = study_base + (k % n_studies)
            events.append((t, name, study, deadline))
        study_base += n_studies
    events.sort(key=lambda e: e[0])
    return events


def _build(specs, *, journal_dir=None, fi=None, args):
    S = sum(s[2] for s in specs)
    objs = [make_objective("sphere", args.D, seed=i) for i in range(S)]
    spaces = [BoxSpace.cube(args.D, *o.bounds) for o in objs]
    tenants, base = [], 0
    for name, w, n_studies, _r, deadline, _n in specs:
        tenants.append(TenantConfig(
            name, weight=w, studies=tuple(range(base, base + n_studies)),
            deadline=deadline))
        base += n_studies
    fs = FleetSampler(spaces, seed=0, slots=min(args.slots, S),
                      n_startup_trials=args.n_startup, n_restarts=args.B,
                      pad_multiple=args.pad, posterior_backend="xla",
                      refit_interval=args.refit_interval,
                      journal_dir=journal_dir, fault_injector=fi,
                      mso_options=MsoOptions())
    if args.debug_nans:
        install_nan_guard(fs.fleet)
    svc = BOService(fs, tenants, max_retries=3, backoff_base=0.01,
                    backoff_cap=0.1)
    return svc, objs


def _pump(svc, objs, events, state, deadline_guard=120.0):
    """Drive the open-loop schedule to completion: submit due arrivals,
    step the service, tell finished asks.  ``state`` carries the cursor
    and told-set so a chaos run can resume mid-schedule."""
    t0 = state.setdefault("t0", time.perf_counter())
    inflight = state.setdefault("inflight", [])
    i = state.get("cursor", 0)
    guard = time.perf_counter() + deadline_guard
    while True:
        now = time.perf_counter() - t0
        while i < len(events) and events[i][0] <= now:
            _t, tenant, study, deadline = events[i]
            i += 1
            state["cursor"] = i
            try:
                inflight.append(svc.submit_ask(tenant, study,
                                               deadline=deadline))
            except FleetFullError:
                state["n_rejected"] = state.get("n_rejected", 0) + 1
        svc.service_step()
        still = []
        for req in inflight:
            if req.state == "done":
                svc.submit_tell(req.tenant, req.study,
                                req.result.trial_id,
                                objs[req.study](req.result.x))
            elif not req.done:
                still.append(req)
        inflight[:] = still
        if i >= len(events) and not inflight:
            return time.perf_counter() - t0
        if time.perf_counter() > guard:
            raise SystemExit(f"bo_serve: schedule stalled "
                             f"({len(inflight)} in flight, "
                             f"{len(events) - i} not yet due)")
        if i < len(events) and not svc.queue_depth() and not inflight:
            # idle until the next arrival (open-loop: never early)
            time.sleep(min(events[i][0] - now, 0.05))


def _tenant_rows(svc, mix, wall):
    rows = []
    snap = svc.stats_snapshot()
    for name, t in snap["svc_tenants"].items():
        lat = np.asarray(svc.tenant_latencies(name))
        rows.append({
            "mode": "serve", "mix": mix, "tenant": name,
            "weight": t["weight"], "submitted": t["submitted"],
            "served": t["served"], "shed": t["shed"],
            "deadline_miss": t["deadline_miss"],
            "rejected": t["rejected"], "retries": t["retries"],
            "p50_ms": (round(1e3 * float(np.quantile(lat, 0.5)), 3)
                       if lat.size else None),
            "p99_ms": (round(1e3 * float(np.quantile(lat, 0.99)), 3)
                       if lat.size else None),
        })
    return rows


def _overall_row(svc, mix, wall, extra=None):
    snap = svc.stats_snapshot()
    lats = np.asarray([x for name in snap["svc_tenants"]
                       for x in svc.tenant_latencies(name)])
    n_buckets = len({blk.bucket for blk in svc.fs.fleet._blocks}) or 1
    row = {
        "mode": "serve_overall", "mix": mix,
        "wall_s": round(wall, 3),
        "completed": snap["svc_completed"],
        "goodput_sps": snap["svc_completed"] / wall,
        "deadline_miss": snap["svc_deadline_miss"],
        "shed": snap["svc_shed"],
        "rejected": snap["svc_rejected"],
        "retries": snap["svc_retries"],
        "rung_changes": snap["svc_rung_changes"],
        "p50_ms": (round(1e3 * float(np.quantile(lats, 0.5)), 3)
                   if lats.size else None),
        "p99_ms": (round(1e3 * float(np.quantile(lats, 0.99)), 3)
                   if lats.size else None),
        "n_buckets": n_buckets,
        "n_compiles_total": snap["n_fleet_compiles"],
        "retrace_causes": snap["retraces"]["causes"],
        **(extra or {}),
    }
    return row


def run_mix(mix, specs, args):
    svc, objs = _build(specs, args=args)
    events = _arrivals(specs, args.seed)
    wall = _pump(svc, objs, events, {})
    extra = ({"nan_guard": nan_guard_stats(svc.fs.fleet)}
             if args.debug_nans else None)
    rows = _tenant_rows(svc, mix, wall) + \
        [_overall_row(svc, mix, wall, extra)]
    over = rows[-1]
    print(f"serve_bench,{mix},completed={over['completed']},"
          f"goodput={over['goodput_sps']:.2f}/s,p50={over['p50_ms']}ms,"
          f"p99={over['p99_ms']}ms,miss={over['deadline_miss']},"
          f"shed={over['shed']},compiles={over['n_compiles_total']}",
          flush=True)
    if args.check_compiles:
        assert over["n_compiles_total"] <= 3 * over["n_buckets"], \
            f"{mix}: {over['n_compiles_total']} traces for " \
            f"{over['n_buckets']} buckets (must be <= 3/bucket); " \
            f"retrace causes: {over['retrace_causes']}"
        if mix == "skew":
            by = {r["tenant"]: r for r in rows if r.get("tenant")}
            light, heavy = by["light"], by["heavy"]
            assert light["shed"] == 0 and light["deadline_miss"] == 0, \
                f"skew: light tenant starved: {light}"
            assert light["p99_ms"] is not None and \
                light["p99_ms"] <= heavy["p99_ms"], \
                f"skew: light p99 {light['p99_ms']}ms not bounded by " \
                f"flooding tenant's {heavy['p99_ms']}ms"
            print(f"serve_bench,{mix},fairness check OK "
                  f"(light p99={light['p99_ms']}ms <= heavy "
                  f"p99={heavy['p99_ms']}ms, light shed=0)", flush=True)
        print(f"serve_bench,{mix},compile check OK "
              f"({over['n_compiles_total']} traces)", flush=True)
    return rows


def run_chaos(args):
    """Kill-and-recover under load: the skew mix, journaled, with
    injected refit/tell latency and a process kill ~60% through the
    expected journal stream."""
    from faults import FaultInjector
    from repro.bo.journal import InjectedCrash

    specs = _tenant_specs(args)["skew"]
    events = _arrivals(specs, args.seed)
    # ~4 records per served request (svc_ask, svc_dispatch, ask, tell)
    kill_seq = max(4, int(0.6 * 4 * len(events)))
    fi = FaultInjector(kill_at_seq=kill_seq,
                       full_latency={0: (0.02, 3)},
                       tell_latency=(0.005, 5))
    d = tempfile.mkdtemp(prefix="bo_serve_chaos_")
    svc, objs = _build(specs, journal_dir=d, fi=fi, args=args)
    state = {}
    t0 = time.perf_counter()
    crashed = False
    try:
        _pump(svc, objs, events, state)
    except InjectedCrash:
        crashed = True
    wall1 = time.perf_counter() - t0
    if not crashed:
        shutil.rmtree(d)
        raise SystemExit(f"--chaos: kill_seq={kill_seq} never reached "
                         f"(--requests too small)")
    completed_pre = svc.n_completed

    t0 = time.perf_counter()
    svc2, rep = BOService.recover(d)
    recover_wall = time.perf_counter() - t0
    if args.debug_nans:
        install_nan_guard(svc2.fs.fleet)
    # re-tell what was in flight at the kill, serve the restored queue,
    # then finish the arrival schedule (the remaining events are all
    # "due" — the outage consumed their arrival times)
    for i, tid in rep.pending:
        svc2.submit_tell(svc2._study_owner[i], i, tid,
                         objs[i](svc2.fs.samplers[i].trials[tid].x))
    t0 = time.perf_counter()
    state2 = {"cursor": state.get("cursor", 0),
              "inflight": list(svc2.recovered["queued"]),
              "t0": t0 - (events[state["cursor"] - 1][0]
                          if state.get("cursor") else 0.0)}
    wall2 = _pump(svc2, objs, events, state2)
    wall2 = time.perf_counter() - t0
    svc2.drain()

    snap = svc2.stats_snapshot()
    n_buckets = len({blk.bucket for blk in svc2.fs.fleet._blocks}) or 1
    completed = completed_pre + snap["svc_completed"]
    total_wall = wall1 + recover_wall + wall2
    row = {
        "mode": "serve_chaos", "mix": "skew",
        "kill_seq": kill_seq,
        "n_records": rep.n_records,
        "truncated_bytes": rep.truncated_bytes,
        "replay_ms": round(rep.replay_ms, 3),
        "recover_wall_ms": round(1e3 * recover_wall, 3),
        "inflight_at_crash": len(rep.pending),
        "restored_queue": len(svc2.recovered["queued"]),
        "injected_delay_s": round(fi.injected_delay_s, 3),
        "completed": completed,
        "goodput_sps": completed / total_wall,
        "goodput_pre_crash_sps": completed_pre / wall1,
        "goodput_post_recovery_sps": (snap["svc_completed"] / wall2
                                      if wall2 > 0 else None),
        "deadline_miss": snap["svc_deadline_miss"],
        "shed": snap["svc_shed"],
        "retries": snap["svc_retries"],
        "n_buckets": n_buckets,
        "n_compiles_total": snap["n_fleet_compiles"],
        "retrace_causes": snap["retraces"]["causes"],
    }
    if args.debug_nans:
        row["nan_guard"] = nan_guard_stats(svc2.fs.fleet)
    print(f"serve_bench,chaos,kill_seq={kill_seq},"
          f"goodput={row['goodput_sps']:.2f}/s "
          f"(pre={row['goodput_pre_crash_sps']:.2f},"
          f"post={row['goodput_post_recovery_sps']:.2f}),"
          f"inflight_at_crash={row['inflight_at_crash']},"
          f"miss={row['deadline_miss']},shed={row['shed']},"
          f"compiles={row['n_compiles_total']}", flush=True)
    if args.check_compiles:
        assert rep.truncated_bytes > 0, \
            "chaos: injected kill left no torn record"
        assert row["goodput_sps"] > 0 and completed > 0, \
            "chaos: no goodput through the incident"
        assert fi.n_full_delays > 0 or fi.n_tell_delays > 0, \
            "chaos: latency injection never fired"
        assert row["n_compiles_total"] <= 3 * n_buckets, \
            f"chaos: {row['n_compiles_total']} traces for {n_buckets} " \
            f"buckets after recovery (must be <= 3/bucket); " \
            f"retrace causes: {row['retrace_causes']}"
        print(f"serve_bench,chaos,checks OK (recovered, goodput "
              f"{row['goodput_sps']:.2f}/s, {row['n_compiles_total']} "
              f"traces)", flush=True)
    shutil.rmtree(d)
    return [row]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: few requests, small GP buckets")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per (unit-rate) tenant")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", action="store_true",
                    help="add a journaled kill-and-recover row with "
                    "latency injection")
    ap.add_argument("--check-compiles", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="enable the obs span tracer (off by default); "
                    "adds a per-phase breakdown to the summary and "
                    "writes the Chrome-trace JSON to --trace-out")
    ap.add_argument("--trace-out", default="BENCH_serve_trace.json")
    ap.add_argument("--debug-nans", action="store_true",
                    help="wrap the three fleet block programs in a "
                    "finite-guard: every float leaf entering/leaving "
                    "them is checked; raises NonFiniteError naming the "
                    "program and leaf (one host sync per call)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.tiny:
        args.requests = args.requests or 8
        args.D, args.B, args.pad = 3, 4, 8
        args.refit_interval, args.n_startup = 4, 4
        args.slots = 4
    else:
        args.requests = args.requests or 24
        args.D, args.B, args.pad = 4, 8, 16
        args.refit_interval, args.n_startup = 4, 6
        args.slots = 8
    args.rate_mid, args.rate_burst, args.rate_low = 20.0, 200.0, 4.0
    args.light_deadline = 60.0

    if args.trace:
        obs_trace.enable()

    rows = []
    for mix, specs in _tenant_specs(args).items():
        rows.extend(run_mix(mix, specs, args))
    if args.chaos:
        rows.extend(run_chaos(args))

    summary = {}
    if args.trace:
        events = obs_trace.get().events()
        summary["phase_breakdown"] = obs_export.phase_breakdown(events)
        obs_export.write_chrome_trace(
            args.trace_out, events, process_name="bo_serve",
            meta={"bench": "bo_serve"})
        print(f"wrote {args.trace_out} ({len(events)} trace events)")
    for r in rows:
        if r["mode"] == "serve_overall":
            m = r["mix"]
            summary[f"{m}_goodput_sps"] = r["goodput_sps"]
            summary[f"{m}_p50_ms"] = r["p50_ms"]
            summary[f"{m}_p99_ms"] = r["p99_ms"]
            summary[f"{m}_deadline_miss"] = r["deadline_miss"]
            summary[f"{m}_shed"] = r["shed"]
            summary[f"{m}_retrace_causes"] = r["retrace_causes"]
            if "nan_guard" in r:
                summary[f"{m}_nan_guard_checks"] = \
                    r["nan_guard"]["n_guard_checks"]
        elif r["mode"] == "serve":
            # per-tenant tails for every mix (the obs snapshot schema
            # carries the counters; latency quantiles live here)
            summary[f"{r['mix']}_{r['tenant']}_p50_ms"] = r["p50_ms"]
            summary[f"{r['mix']}_{r['tenant']}_p99_ms"] = r["p99_ms"]
        elif r["mode"] == "serve_chaos":
            summary["chaos_goodput_sps"] = r["goodput_sps"]
            summary["chaos_goodput_post_recovery_sps"] = \
                r["goodput_post_recovery_sps"]
            summary["chaos_inflight_at_crash"] = r["inflight_at_crash"]
            summary["chaos_deadline_miss"] = r["deadline_miss"]
            summary["chaos_shed"] = r["shed"]
            summary["chaos_retrace_causes"] = r["retrace_causes"]

    record = {
        "bench": "bo_serve",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "device": jax.devices()[0].device_kind,
        "jax_backend": jax.default_backend(),
        "python": platform.python_version(),
        "mode": "tiny" if args.tiny else "default",
        "requests": args.requests,
        "seed": args.seed,
        "summary": summary,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
