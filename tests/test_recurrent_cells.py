"""Recurrent-cell math: chunkwise-parallel forms vs step-by-step references.

These validate the TPU-native reformulations (associative scan, chunkwise
mLSTM) against the literal per-step recurrences from the papers.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.xlstm import mlstm_chunkwise, mlstm_step
from repro.models.rglru import _rg_lru

KEY = jax.random.PRNGKey(0)


def test_mlstm_chunkwise_matches_recurrent():
    B, H, S, dk, dv = 2, 3, 32, 8, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, dk), jnp.float64)
    k = jax.random.normal(ks[1], (B, H, S, dk), jnp.float64)
    v = jax.random.normal(ks[2], (B, H, S, dv), jnp.float64)
    logf = jax.nn.log_sigmoid(
        jax.random.normal(ks[3], (B, H, S), jnp.float64) + 1.0)
    logi = jax.random.normal(ks[4], (B, H, S), jnp.float64) * 0.5

    for chunk in (4, 8, 16, 32):
        h_ck, state_ck = mlstm_chunkwise(q, k, v, logf, logi, chunk)
        # literal recurrence
        state = None
        outs = []
        C = jnp.zeros((B, H, dk, dv), jnp.float64)
        n = jnp.zeros((B, H, dk), jnp.float64)
        m = jnp.full((B, H), -1e30, jnp.float64)
        st = (C, n, m)
        for t in range(S):
            h_t, st = mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                 logf[:, :, t], logi[:, :, t], st)
            outs.append(h_t)
        h_ref = jnp.stack(outs, axis=2)
        err = float(jnp.max(jnp.abs(h_ck - h_ref)))
        assert err < 1e-8, (chunk, err)
        # final states agree too (chunk boundary carry correctness)
        for a, b in zip(state_ck, st):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-8)


def test_mlstm_state_continuation():
    """Processing [first half] then [second half with carried state] ==
    processing the whole sequence."""
    B, H, S, dk, dv = 1, 2, 16, 4, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, dk), jnp.float64)
    k = jax.random.normal(ks[1], (B, H, S, dk), jnp.float64)
    v = jax.random.normal(ks[2], (B, H, S, dv), jnp.float64)
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S),
                                                jnp.float64))
    logi = jax.random.normal(ks[4], (B, H, S), jnp.float64) * 0.3

    h_full, _ = mlstm_chunkwise(q, k, v, logf, logi, 4)
    half = S // 2
    h1, st = mlstm_chunkwise(q[:, :, :half], k[:, :, :half],
                             v[:, :, :half], logf[:, :, :half],
                             logi[:, :, :half], 4)
    h2, _ = mlstm_chunkwise(q[:, :, half:], k[:, :, half:],
                            v[:, :, half:], logf[:, :, half:],
                            logi[:, :, half:], 4, state=st)
    err = float(jnp.max(jnp.abs(jnp.concatenate([h1, h2], 2) - h_full)))
    assert err < 1e-8, err


def test_rglru_assoc_scan_matches_sequential():
    B, S, W = 2, 24, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, W), jnp.float64)
    r = jax.random.normal(ks[1], (B, S, W), jnp.float64)
    i = jax.random.normal(ks[2], (B, S, W), jnp.float64)
    lam = jax.random.normal(ks[3], (W,), jnp.float64) * 0.3 + 0.7

    h_par, h_last = _rg_lru(x, r, i, lam)

    # literal sequential recurrence
    C = 8.0
    log_a = -C * jax.nn.softplus(lam)[None, :] * jax.nn.sigmoid(r)
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i) * x
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * gated
    h = jnp.zeros((B, W), jnp.float64)
    outs = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        outs.append(h)
    h_ref = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_ref),
                               atol=1e-10)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref[:, -1]),
                               atol=1e-10)


def test_rglru_state_continuation():
    B, S, W = 1, 16, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, W), jnp.float64)
    r = jax.random.normal(ks[1], (B, S, W), jnp.float64)
    i = jax.random.normal(ks[2], (B, S, W), jnp.float64)
    lam = jnp.full((W,), 0.7, jnp.float64)
    h_full, _ = _rg_lru(x, r, i, lam)
    half = S // 2
    h1, carry = _rg_lru(x[:, :half], r[:, :half], i[:, :half], lam)
    h2, _ = _rg_lru(x[:, half:], r[:, half:], i[:, half:], lam, h0=carry)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], 1)), np.asarray(h_full),
        atol=1e-10)
