"""Run the rule set over a project and render JSON / human reports."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from .baseline import Baseline
from .core import Finding, Project, Rule, SEV_ERROR


def run_rules(project: Project, rules: Sequence[Rule]) -> List[Finding]:
    findings: List[Finding] = []
    for rule in rules:
        for mod in project.modules:
            findings.extend(rule.run(mod, project))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings


class Report:
    """Findings split into actionable / suppressed / baselined."""

    def __init__(self, project: Project, findings: List[Finding],
                 baseline: Baseline):
        self.open: List[Finding] = []          # must be fixed or triaged
        self.suppressed: List[dict] = []       # inline allows (with reason)
        self.baselined: List[dict] = []
        mods = {m.rel: m for m in project.modules}
        for f in findings:
            mod = mods.get(f.file)
            allow = mod.allow_for(f) if mod else None
            if allow is not None:
                if not allow[1]:
                    f.message += ("  [inline allow has no reason — "
                                  "suppression rejected]")
                    self.open.append(f)
                else:
                    self.suppressed.append({**f.to_json(),
                                            "reason": allow[1]})
                continue
            ent = baseline.match(f)
            if ent is not None:
                self.baselined.append({**f.to_json(),
                                       "reason": ent.get("reason", "")})
                continue
            self.open.append(f)
        # malformed baseline entries surface as findings too
        self.open.extend(baseline.reasonless())
        self.stale_baseline = baseline.stale()

    @property
    def failed(self) -> bool:
        return bool(self.open)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.open:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_json(self) -> dict:
        return {
            "open": [f.to_json() for f in self.open],
            "suppressed": self.suppressed,
            "baselined": self.baselined,
            "stale_baseline": self.stale_baseline,
            "counts": self.counts(),
            "n_open": len(self.open),
            "n_suppressed": len(self.suppressed),
            "n_baselined": len(self.baselined),
        }

    def write_json(self, path: Path) -> None:
        path.write_text(json.dumps(self.to_json(), indent=2) + "\n")

    def render(self) -> str:
        lines: List[str] = []
        if self.open:
            lines.append(f"{len(self.open)} open finding(s):")
            by_rule: Dict[str, List[Finding]] = {}
            for f in self.open:
                by_rule.setdefault(f.rule, []).append(f)
            for rule in sorted(by_rule):
                lines.append(f"\n[{rule}] ({len(by_rule[rule])})")
                for f in by_rule[rule]:
                    lines.append(f"  {f.file}:{f.line}: {f.message}"
                                 + (f"  (in {f.func})" if f.func else ""))
                    if f.snippet:
                        lines.append(f"      > {f.snippet}")
        else:
            lines.append("no open findings")
        if self.baselined:
            lines.append(f"\n{len(self.baselined)} baselined "
                         f"(accepted with reasons)")
        if self.suppressed:
            lines.append(f"{len(self.suppressed)} inline-suppressed")
        for e in self.stale_baseline:
            lines.append(f"stale baseline entry: [{e.get('rule')}] "
                         f"{e.get('file')} {e.get('func') or ''} — "
                         f"source line no longer matches; prune it")
        return "\n".join(lines)
