"""Serving launcher: spin up the continuous-batching engine on an arch.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 12 --prompt-len 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.shapes import init_fn_for
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(attn_chunk=min(cfg.attn_chunk, args.max_len))
    if cfg.family == "encdec":
        raise SystemExit("use whisper.decode_step directly for encdec")

    params = init_fn_for(cfg)(jax.random.PRNGKey(args.seed), cfg)
    eng = ServeEngine(params, cfg, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))

    t0 = time.time()
    done = eng.run_until_drained()
    wall = time.time() - t0
    print(f"[serve] {len(done)} requests, {eng.stats['tokens']} tokens, "
          f"{eng.stats['steps']} steps, {wall:.1f}s "
          f"({eng.stats['tokens'] / max(wall, 1e-9):.1f} tok/s)")
    for r in done[:4]:
        print(f"  uid={r.uid} out={r.out_tokens}")


if __name__ == "__main__":
    main()
