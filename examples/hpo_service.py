"""Multi-tenant HPO through the BO service: several model-zoo training
configurations share ONE fleet plane behind :class:`BOService`.

Each tenant is one architecture sweep — it owns a study, submits ask
requests through the service's asyncio facade, trains a reduced LM for a
few steps at the suggested (log lr, log weight decay), and tells the
final loss back.  Tenants run as independent coroutines at their own
pace (the big model trains slower, so its asks arrive sparser), while
the service task multiplexes everything onto the fleet under
deficit-round-robin fairness: the fast tenant's flood of requests cannot
starve the slow one, and all suggests still compile into the same <=3
fleet programs per (bucket, slots) shape.

Reduced scale by default so it runs on CPU in minutes:

    PYTHONPATH=src python examples/hpo_service.py
"""
import argparse
import asyncio

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.bo.sampler import FleetSampler         # noqa: E402
from repro.bo.space import BoxSpace               # noqa: E402
from repro.configs import get_config              # noqa: E402
from repro.core.mso import MsoOptions             # noqa: E402
from repro.data.synth import DataConfig, synth_batch   # noqa: E402
from repro.models import lm                       # noqa: E402
from repro.serve.bo_service import BOService, TenantConfig  # noqa: E402
from repro.train.optim import OptimConfig, init_opt_state   # noqa: E402
from repro.train.step import make_train_step      # noqa: E402

SPACE = BoxSpace(np.array([-5.0, -4.0]), np.array([-1.0, -0.5]))


def make_trial_fn(arch, width, layers, steps, batch, seq):
    cfg = get_config(arch).reduced().replace(
        dtype="float32", attn_chunk=32, d_model=width,
        n_layers=layers, d_ff=2 * width)
    dcfg = DataConfig(global_batch=batch, seq_len=seq, seed=0)

    def trial(x) -> float:
        log_lr, log_wd = float(x[0]), float(x[1])
        opt_cfg = OptimConfig(lr=10.0 ** log_lr,
                              weight_decay=10.0 ** log_wd,
                              warmup_steps=max(steps // 10, 1),
                              total_steps=steps)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg))
        loss = 20.0
        for i in range(steps):
            b = {k: jnp.asarray(v)
                 for k, v in synth_batch(cfg, dcfg, i).items()}
            params, opt_state, m = step(params, opt_state, b)
            loss = float(m["loss"])
            if not np.isfinite(loss):
                return 20.0
        return loss

    return trial


async def tenant_task(svc, name, study, trial_fn, n_trials):
    """One architecture sweep: ask → train → tell, at its own pace."""
    for _ in range(n_trials):
        t = await svc.ask(name, study)
        # training is synchronous compute; yield around it so the
        # service and the other tenants keep running between trials
        y = await asyncio.get_event_loop().run_in_executor(
            None, trial_fn, t.x)
        await svc.tell(name, study, t.trial_id, y)
        print(f"[{name}] trial {t.trial_id}: "
              f"log_lr={t.x[0]:+.2f} log_wd={t.x[1]:+.2f} "
              f"-> loss {y:.4f}", flush=True)
    best = svc.fs.samplers[study].best()
    print(f"[{name}] best: lr=10^{best.x[0]:.2f} "
          f"wd=10^{best.x[1]:.2f} loss={best.y:.4f}", flush=True)


async def serve(args):
    zoo = [
        # (tenant, arch, weight, width, layers, steps)
        ("small-fast", "llama3.2-3b", 1.0, 64, 2, args.steps),
        ("base", "llama3.2-3b", 2.0, args.width, args.layers, args.steps),
    ]
    fs = FleetSampler([SPACE] * len(zoo), seed=0, n_startup_trials=4,
                      n_restarts=6, pad_multiple=8, slots=4,
                      posterior_backend="xla", refit_interval=2,
                      mso_options=MsoOptions(maxiter=100, pgtol=1e-2))
    svc = BOService(fs, [
        TenantConfig(name, weight=w, studies=(i,))
        for i, (name, _a, w, *_rest) in enumerate(zoo)])
    server = asyncio.create_task(svc.run())
    await asyncio.gather(*[
        tenant_task(svc, name, i,
                    make_trial_fn(arch, width, layers, steps,
                                  args.batch, args.seq), args.trials)
        for i, (name, arch, _w, width, layers, steps) in enumerate(zoo)])
    svc.stop()
    await server
    snap = svc.stats_snapshot()
    print(f"\nservice: {snap['svc_completed']} asks served, "
          f"p99={snap['svc_p99_s']}, rung={snap['svc_rung']}, "
          f"fleet compiles={snap['n_fleet_compiles']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    asyncio.run(serve(args))


if __name__ == "__main__":
    main()
