"""Quickstart: Bayesian optimization with D-BE acquisition optimization.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.bo.objectives import make_objective     # noqa: E402
from repro.bo.sampler import GPSampler             # noqa: E402
from repro.bo.space import BoxSpace                # noqa: E402
from repro.core.mso import MsoOptions              # noqa: E402


def main():
    D = 5
    obj = make_objective("rastrigin", D, seed=1)
    space = BoxSpace.cube(D, *obj.bounds)

    sampler = GPSampler(
        space,
        strategy="dbe",               # the paper's coroutine D-BE
        n_startup_trials=10,
        n_restarts=10,                # B=10 multi-start (paper setting)
        mso_options=MsoOptions(m=10, maxiter=200, pgtol=1e-2),
        seed=0,
    )
    best = sampler.optimize(obj, n_trials=40)
    print(f"best value: {best.y:.4f} at x = {np.round(best.x, 3)}")
    print(f"GP fits: {sampler.stats.n_gp_fits}, "
          f"acqf time: {sampler.stats.acqf_time:.1f}s, "
          f"median L-BFGS-B iters/trial: "
          f"{np.median(sampler.stats.acqf_iters):.1f}")


if __name__ == "__main__":
    main()
