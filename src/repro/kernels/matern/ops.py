"""Public Matérn-5/2 ops with backend dispatch.

``backend="pallas"`` targets TPU (or ``interpret=True`` for CPU
validation); ``backend="xla"`` is the pure-jnp path used by the CPU BO
benchmarks.

``matern52_posterior_op`` is the engine's hot evaluation backend: the
fused cross-gram + mean/variance posterior.  The Pallas forward carries a
custom VJP whose backward re-derives gradients from the jnp oracle — QN
optimizers (which need ``∇acq`` every evaluation) get the fused forward
*and* exact gradients without a hand-written transposed kernel.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax

from repro.kernels.matern.kernel import matern52_gram, matern52_posterior
from repro.kernels.matern.ref import (matern52_gram_ref,
                                      matern52_posterior_ref)


def matern52_cross(x1: jax.Array, x2: jax.Array, inv_lengthscale: jax.Array,
                   amplitude: jax.Array, *, backend: str = "xla",
                   interpret: bool = False) -> jax.Array:
    if backend == "pallas":
        return matern52_gram(x1, x2, inv_lengthscale, amplitude,
                             interpret=interpret)
    if backend == "xla":
        return matern52_gram_ref(x1, x2, inv_lengthscale, amplitude)
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _posterior_pallas(xq, xt, alpha, kinv, inv_lengthscale, amplitude,
                      interpret):
    return matern52_posterior(xq, xt, alpha, kinv, inv_lengthscale,
                              amplitude, interpret=interpret)


def _posterior_fwd(xq, xt, alpha, kinv, inv_lengthscale, amplitude,
                   interpret):
    out = matern52_posterior(xq, xt, alpha, kinv, inv_lengthscale,
                             amplitude, interpret=interpret)
    return out, (xq, xt, alpha, kinv, inv_lengthscale, amplitude)


def _posterior_bwd(interpret, residuals, cotangents):
    del interpret
    _, vjp = jax.vjp(matern52_posterior_ref, *residuals)
    return vjp(cotangents)


_posterior_pallas.defvjp(_posterior_fwd, _posterior_bwd)


def matern52_posterior_op(xq: jax.Array, xt: jax.Array, alpha: jax.Array,
                          kinv: jax.Array, inv_lengthscale: jax.Array,
                          amplitude: jax.Array, *, backend: str = "xla",
                          interpret: bool = False
                          ) -> Tuple[jax.Array, jax.Array]:
    """Fused GP posterior ((q,) mean, (q,) var); differentiable on every
    backend.  ``kinv`` is the precomputed K⁻¹ of the training gram."""
    if backend == "pallas":
        return _posterior_pallas(xq, xt, alpha, kinv, inv_lengthscale,
                                 amplitude, interpret)
    if backend == "xla":
        return matern52_posterior_ref(xq, xt, alpha, kinv, inv_lengthscale,
                                      amplitude)
    raise ValueError(f"unknown backend {backend!r}")
