"""Public op: fused GP-mean kernel-vector product with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.kvp.kernel import kvp
from repro.kernels.kvp.ref import kvp_ref


def gp_mean_kvp(xq: jax.Array, xt: jax.Array, alpha: jax.Array,
                inv_lengthscale: jax.Array, amplitude: jax.Array,
                *, backend: str = "xla", interpret: bool = False) -> jax.Array:
    if backend == "pallas":
        return kvp(xq, xt, alpha, inv_lengthscale, amplitude,
                   interpret=interpret)
    if backend == "xla":
        return kvp_ref(xq, xt, alpha, inv_lengthscale, amplitude)
    raise ValueError(f"unknown backend {backend!r}")
