"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop BODY once — a
48-layer scan × 16-microbatch scan under-reports FLOPs/bytes/collectives by
~2-3 orders of magnitude.  This walks the computation call graph from ENTRY,
multiplying loop bodies by their ``known_trip_count`` backend config, and
accumulates:

  * flops        — 2 · numel(result) · contracted_size for every dot
                   (convolutions are absent from this framework's graphs)
  * bytes        — Σ (result + operand bytes) per op (HBM-traffic proxy,
                   same spirit as XLA's "bytes accessed")
  * collectives  — per-type result bytes + counts

Used by launch/dryrun.py for the roofline terms.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(")
_PARAM_RE = re.compile(r"%?([\w\.\-_]+):\s*((?:pred|[suf]\d+|bf16|c64|c128)\[[\d,]*\][^,)]*)")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-_]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-_]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-_]+)")
_TRIP_RE = re.compile(r'known_trip_count..?:\{"n":"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")


def _type_info(type_str: str) -> Tuple[int, int]:
    """(total elements across tuple parts, total bytes)."""
    numel_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES.get(dt, 4)
    return numel_total, bytes_total


class _Comp:
    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {op: {"bytes": 0.0, "count": 0} for op in
                     COLLECTIVE_OPS}
        # (name, trip_multiplier, kind: control|fusion)
        self.children: List[Tuple[str, int, str]] = []


def _parse(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    symbols: Dict[str, str] = {}
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        hdr = _COMP_HDR.match(stripped) \
            if (stripped.endswith("{") and "->" in stripped
                and not stripped.startswith(("%", " ")) or
                (stripped.endswith("{") and "->" in stripped
                 and stripped.startswith("%"))) else None
        if hdr:
            name = hdr.group(1)
            cur = _Comp()
            comps[name] = cur
            if line.strip().startswith("ENTRY"):
                entry = name
            symbols = {}
            for pn, pt in _PARAM_RE.findall(line):
                symbols[pn] = pt
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        res_name, res_type, opcode = m.groups()
        symbols[res_name] = res_type
        _, res_bytes = _type_info(res_type)
        after = line[m.end():]
        paren = after.split(")", 1)[0]

        # HBM-traffic proxy: every materialized result is written once and
        # read ~once downstream (×2).  Metadata/aliasing ops move nothing;
        # while/call results are materialized by their bodies, not here.
        if opcode == "dynamic-update-slice":
            # in-place slice write: traffic = the update operand, not the
            # (aliased) full buffer the op nominally returns
            ops_ = _OPERAND_RE.findall(paren)
            upd = ops_[1] if len(ops_) > 1 else None
            ub = _type_info(symbols[upd])[1] if upd in symbols else 0
            cur.bytes += 2.0 * (ub if ub else res_bytes)
        elif opcode not in ("tuple", "get-tuple-element", "parameter",
                            "constant", "bitcast", "while", "conditional",
                            "call", "custom-call"):
            cur.bytes += 2.0 * res_bytes

        if opcode == "dot":
            # contracted size from lhs shape + contracting dims
            k = 1
            dm = _DIMS_RE.search(line)
            ops = _OPERAND_RE.findall(paren)
            if dm and ops and ops[0] in symbols:
                lhs_dims = []
                sm = _SHAPE_RE.search(symbols[ops[0]])
                if sm:
                    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                for d in dm.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            numel, _ = _type_info(res_type)
            cur.flops += 2.0 * numel * k
        else:
            base = opcode.split("-start")[0]
            if base in COLLECTIVE_OPS and not opcode.endswith("-done"):
                cur.coll[base]["bytes"] += _type_info(res_type)[1]
                cur.coll[base]["count"] += 1

        if opcode == "while":
            body = _BODY_RE.search(line)
            cond = _COND_RE.search(line)
            trip = _TRIP_RE.search(line)
            tc = int(trip.group(1)) if trip else 1
            if body:
                cur.children.append((body.group(1), tc, "control"))
            if cond:
                cur.children.append((cond.group(1), tc, "control"))
        else:
            cm = _CALLS_RE.search(line)
            if cm:
                # fusion bodies are register-local: their internal ops are
                # NOT HBM traffic (the fusion result already counted); they
                # may still contain dots → flops/collectives descend.
                kind = "fusion" if opcode == "fusion" else "control"
                cur.children.append((cm.group(1), 1, kind))

    comps["__entry__"] = comps.get(entry, _Comp()) if entry else _Comp()
    comps["__entry_name__"] = entry        # type: ignore
    return comps


def analyze(text: str) -> dict:
    """→ {"flops", "bytes", "collectives": {op: {bytes, count}}} with
    while bodies scaled by known_trip_count."""
    comps = _parse(text)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    memo: Dict[str, dict] = {}

    def cost(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 50:
            return {"flops": 0.0, "bytes": 0.0,
                    "coll": {op: {"bytes": 0.0, "count": 0}
                             for op in COLLECTIVE_OPS}}
        out = {"flops": c.flops, "bytes": c.bytes,
               "coll": {op: dict(v) for op, v in c.coll.items()}}
        for child, mult, kind in c.children:
            sub = cost(child, depth + 1)
            out["flops"] += mult * sub["flops"]
            if kind == "control":
                out["bytes"] += mult * sub["bytes"]
            for op in COLLECTIVE_OPS:
                out["coll"][op]["bytes"] += mult * sub["coll"][op]["bytes"]
                out["coll"][op]["count"] += mult * sub["coll"][op]["count"]
        memo[name] = out
        return out

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {op: {"bytes": 0.0, "count": 0}
                                for op in COLLECTIVE_OPS}}
    total = cost(entry)
    return {"flops": total["flops"], "bytes": total["bytes"],
            "collectives": total["coll"]}
