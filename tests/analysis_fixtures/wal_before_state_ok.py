"""Fixture: WAL-disciplined twins of ``wal_before_state_bad`` — the
journal append dominates every state change.  Must produce zero
``wal-before-state`` findings."""


class Engine:
    def __init__(self):
        self.journal = None
        self.studies = {}
        self.queue = []

    def _journal(self, kind, **fields):
        if self.journal is not None:
            self.journal.append(kind, **fields)

    def journal_then_evict(self, st):
        self._journal("evict", study=st.sid)
        self.studies.pop(st.sid)

    def journal_then_flag(self, st, reason):
        self._journal("shed", study=st.sid, reason=reason)
        st.shed = reason

    def journal_in_both_branches(self, st, slot, ok):
        if ok:
            self._journal("admit", study=st.sid, slot=slot)
        else:
            self._journal("reject", study=st.sid)
            return
        self.studies[slot] = st
