"""Batched acquisition-evaluation engine — one evaluation plane behind
every MSO strategy (seq / cbe / dbe / dbe_vec), the BO sampler, and the
serving path.

Layering:  kernels (Pallas) → gp → **engine** → core.mso → bo / serve.

* :class:`EvalPlan` — static workload description: shape buckets,
  pad-or-shrink schedule, q-batch layout.
* :class:`EvalEngine` — owns the jitted ``(-acq, -∇acq)`` primitive, its
  shape-bucketed cache + compile counters, the host-facing padded
  evaluator, and the device-resident lockstep entry.
* :mod:`~repro.engine.posterior` — pluggable GP-posterior hot path
  (Pallas-fused cross-gram + mean/variance, or classic Cholesky).
* :class:`CountingJit` — the compile-aware jit primitive both this engine
  and the serving engine build on.
"""
from repro.engine.cache import CountingJit
from repro.engine.engine import (BatchEvalFn, EngineStats, EvalEngine,
                                 default_engine)
from repro.engine.plan import EvalPlan, bucket_ladder
from repro.engine.posterior import (BACKENDS, fused_logei_acq, posterior,
                                    resolve_backend)
# The ask/fleet modules import repro.gp.fit, which re-enters repro.core →
# this package: importing them eagerly here would break `import repro.gp`
# as the FIRST repro import (gp.fit would still be mid-initialization when
# ask needs it).  PEP 562 lazy export defers them until first attribute
# access, by which point every layer is fully initialized.
_ASK_EXPORTS = ("AskConfig", "AskEngine", "SuggestInfo")
_FLEET_EXPORTS = ("FleetConfig", "FleetEngine", "FleetFullError",
                  "FleetStudyError")


def __getattr__(name):
    if name in _ASK_EXPORTS:
        from repro.engine import ask
        return getattr(ask, name)
    if name in _FLEET_EXPORTS:
        from repro.engine import fleet
        return getattr(fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "AskConfig", "AskEngine", "BACKENDS", "BatchEvalFn", "CountingJit",
    "EngineStats", "EvalEngine", "EvalPlan", "FleetConfig", "FleetEngine",
    "FleetFullError", "FleetStudyError", "SuggestInfo", "bucket_ladder",
    "default_engine", "fused_logei_acq", "posterior", "resolve_backend",
]
