from repro.bo.space import BoxSpace
from repro.bo.sampler import GPSampler
from repro.bo.objectives import make_objective, OBJECTIVES
