"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run pins the device count before any
jax initialization)."""
from __future__ import annotations

import contextlib

import jax


def _make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API has them
    (jax>=0.5); plain mesh otherwise — semantics match for our usage."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    jax>=0.6 spells this ``jax.set_mesh``; on older releases the Mesh
    object itself is the (physical-mesh) context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext() if mesh is None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips; the
    "pod" axis extends data parallelism across the cross-pod DCN/ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny host-device mesh for tests (requires
    --xla_force_host_platform_device_count >= prod(shape))."""
    return _make_mesh(shape, axes)


def make_fleet_mesh(n_devices=None, axis="study"):
    """1-D mesh for the fleet ask plane: the study axis is embarrassingly
    parallel, so the fleet shards slot blocks over a single ``"study"``
    dimension spanning ``n_devices`` (default: every visible device).
    A 1-device fleet mesh is valid and bit-for-bit equal to running
    unsharded — the placement-independence invariant."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"fleet mesh needs 1 <= n_devices <= {len(devs)} "
                         f"visible devices, got {n}")
    return Mesh(np.asarray(devs[:n]), (axis,))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s/link
HBM_BYTES = 16 * 1024**3        # 16 GiB
