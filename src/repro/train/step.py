"""The jitted train step: loss → grads (microbatched) → AdamW update.

Grad accumulation runs as a `lax.scan` over microbatches — per-microbatch
psum stays independently schedulable, which is what lets XLA's
latency-hiding scheduler overlap the DP all-reduce of microbatch i with the
compute of microbatch i+1 (DESIGN.md §6 "overlap").
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import lm
from repro.models import whisper as wh
from repro.models.config import ModelConfig
from repro.train.optim import (AdamState, OptimConfig, apply_updates,
                               constrain_grads_zero1)

Array = jax.Array


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array]) -> Array:
    if cfg.family == "encdec":
        return wh.lm_loss(params, cfg, batch)
    return lm.lm_loss(params, cfg, batch)


def _cast_grads(grads, mode: str):
    if mode == "bf16":
        # backward collectives carry bf16 (half the DP all-reduce bytes);
        # accumulation below stays fp32.
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    return grads


def compute_grads(params, cfg: ModelConfig, batch, *,
                  grad_accum: int = 1, compression: str = "none",
                  shard_grads: bool = True):
    """(loss, grads) with optional microbatch accumulation.

    ``shard_grads``: constrain gradients to ZeRO-sharded specs (DP
    reduce-scatter instead of all-reduce; fp32 accumulator sharded)."""
    vg = jax.value_and_grad(loss_fn)
    maybe_shard = constrain_grads_zero1 if shard_grads else (lambda g: g)

    if grad_accum <= 1:
        loss, grads = vg(params, cfg, batch)
        grads = _cast_grads(grads, compression)
        return loss, maybe_shard(
            jax.tree.map(lambda g: g.astype(jnp.float32), grads))

    def split(x):
        return x.reshape((grad_accum, x.shape[0] // grad_accum)
                         + x.shape[1:])

    micro = jax.tree.map(split, batch)
    # bf16 compression = genuine bf16 accumulation: the per-microbatch
    # reduce-scatter AND the accumulator both carry bf16 (half the wire
    # bytes + half the accumulator memory).  A post-hoc bf16 round trip
    # would just be convert-folded away by XLA.
    acc_dt = jnp.bfloat16 if compression == "bf16" else jnp.float32
    zero = maybe_shard(
        jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params))

    def body(carry, mb):
        acc, lsum = carry
        loss, grads = vg(params, cfg, mb)
        grads = _cast_grads(grads, compression)
        grads = maybe_shard(grads)
        acc = jax.tree.map(lambda a, g: a + g.astype(acc_dt) /
                           grad_accum, acc, grads)
        return (acc, lsum + loss / grad_accum), None

    (grads, loss), _ = lax.scan(body, (zero, jnp.zeros((), jnp.float32)),
                                micro)
    return loss, jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def train_step(params, opt_state: AdamState, batch, *, cfg: ModelConfig,
               opt_cfg: OptimConfig, grad_accum: int = 1
               ) -> Tuple[Any, AdamState, Dict[str, Array]]:
    loss, grads = compute_grads(params, cfg, batch, grad_accum=grad_accum,
                                compression=opt_cfg.grad_compression,
                                shard_grads=opt_cfg.shard_grads)
    new_params, new_state, metrics = apply_updates(params, grads,
                                                   opt_state, opt_cfg)
    metrics = dict(metrics, loss=loss)
    return new_params, new_state, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: OptimConfig,
                    grad_accum: int = 1):
    """Returns fn(params, opt_state, batch) suitable for jit with donation."""
    def step(params, opt_state, batch):
        return train_step(params, opt_state, batch, cfg=cfg,
                          opt_cfg=opt_cfg, grad_accum=grad_accum)
    return step
