"""Static invariant linter + runtime trace-discipline sanitizers.

``python -m repro.analysis`` runs five AST rule families that encode
the ROADMAP contracts the runtime tests can only spot-check:

* ``wal-before-state``      — journal append dominates the state change
* ``use-after-donate``      — donated buffers are rebound before reads
* ``recompile-hazard``      — jit keys never derive from live studies
* ``host-leak-into-trace``  — no host sync / host state under a trace
* ``nan-hazard``            — benign-row (_FAR) finiteness in carries

The runtime half lives in :mod:`repro.analysis.runtime` (opt-in NaN
guard for the fleet block programs) and in
:class:`repro.engine.cache.CountingJit`'s retrace sanitizer, which
classifies *why* each retrace happened.
"""
from .baseline import Baseline
from .core import Finding, Project, Rule, load_project
from .report import Report, run_rules
from .rules_donate import UseAfterDonateRule
from .rules_nan import NanHazardRule
from .rules_trace import HostLeakRule, RecompileHazardRule
from .rules_wal import WalBeforeStateRule

#: the registered rule set, in documentation order
ALL_RULES = (
    WalBeforeStateRule(),
    UseAfterDonateRule(),
    RecompileHazardRule(),
    HostLeakRule(),
    NanHazardRule(),
)

RULE_IDS = tuple(r.id for r in ALL_RULES)

__all__ = [
    "ALL_RULES", "RULE_IDS", "Baseline", "Finding", "Project", "Report",
    "Rule", "load_project", "run_rules", "UseAfterDonateRule",
    "NanHazardRule", "HostLeakRule", "RecompileHazardRule",
    "WalBeforeStateRule",
]
