"""Logical-axis sharding rules (MaxText-style, shape-aware).

Parameters and activations are annotated with *logical* axis names
("batch", "heads", "ff", ...).  ``pspec`` greedily maps logical names onto
mesh axes, honoring divisibility — so the same model code serves the
single-pod (16,16) mesh, the multi-pod (2,16,16) mesh, and a 1-device CPU
smoke test without edits.  Greedy multi-assignment lets e.g. batch=256
shard over ("pod","data") while kv_heads=8 falls back from "model" to
sharding head_dim instead (the decode-KV memory fix; see DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def get_abstract_mesh():
    """Version-compat ``jax.sharding.get_abstract_mesh``.

    jax<0.5 has no abstract-mesh registry; there the ambient mesh is the
    ``with Mesh(...)`` context's physical mesh, which exposes the same
    ``empty``/``axis_names``/``axis_sizes`` surface the callers use.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh

# Per logical axis: ordered mesh-axis candidates (first match wins).
AXIS_CANDIDATES = {
    "batch": ("pod", "data"),            # training/prefill activations
    "batch_full": ("pod", "data", "model"),  # decode batches spill to model
    "seq": ("seq",),                     # reserved (SP uses explicit rules)
    "seq_sp": ("model",),                # Megatron-SP residual stream
    "kv_seq": ("data",),                 # long-context decode KV sharding
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head": ("model",),                  # fallback when kv_heads indivisible
    "ff": ("model",),
    "experts": ("model",),
    "lru": ("model",),
    "embed": (),
    None: (),
}


def pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
          mesh_axis_names: Sequence[str],
          mesh_shape: Optional[dict] = None) -> P:
    """Greedy shape-aware logical→mesh mapping.

    Each mesh axis is used at most once per tensor; a dim takes as many of
    its candidate axes as divide it (in order).
    """
    if mesh_shape is None:
        mesh_shape = {}
    used = set()
    out = []
    for size, name in zip(shape, axes):
        assigned: list = []
        rem = size
        for cand in AXIS_CANDIDATES.get(name, ()):
            if cand in used or cand not in mesh_axis_names:
                continue
            ax_size = mesh_shape.get(cand, 1)
            if ax_size > 1 and rem % ax_size == 0:
                assigned.append(cand)
                used.add(cand)
                rem //= ax_size
        out.append(tuple(assigned) if len(assigned) > 1
                   else (assigned[0] if assigned else None))
    return P(*out)


# ---------------------------------------------------------------------------
# fleet pspecs: stacked study-axis state (engine/fleet.py)
# ---------------------------------------------------------------------------
# The fleet ask plane stacks whole studies along ONE leading axis; unlike
# model parameters there is no logical-name negotiation — every leaf of the
# stacked state (X (S, b, D), y (S, b), θ (S, P), factors (S, b, b), PRNG
# keys (S, 2)) shards its leading axis over the mesh's study dimension and
# replicates the rest.  These helpers are the fleet-facing analogue of
# ``param_pspecs``.

FLEET_AXIS = "study"


def fleet_pspec(ndim: int, axis: str = FLEET_AXIS) -> P:
    """Leading-study-axis spec: ``P(axis, None, ...)`` for an ndim-leaf."""
    if ndim < 1:
        raise ValueError("fleet state leaves must have a leading study axis")
    return P(axis, *([None] * (ndim - 1)))


def fleet_sharding(mesh: Mesh, ndim: int = 1,
                   axis: Optional[str] = None) -> NamedSharding:
    """NamedSharding splitting the leading study axis of an ndim-leaf over
    a 1-D fleet mesh (``make_fleet_mesh``).  A P() with fewer axes than the
    array rank replicates the trailing dims, so ndim=1 serves every leaf."""
    if axis is None:
        axis = mesh.axis_names[0]
    return NamedSharding(mesh, fleet_pspec(ndim, axis))


def fleet_shardings(mesh: Mesh, tree, axis: Optional[str] = None):
    """Same-structure pytree of leading-study-axis NamedShardings."""
    if axis is None:
        axis = mesh.axis_names[0]
    return jax.tree.map(
        lambda x: fleet_sharding(mesh, jnp.ndim(x), axis), tree)


# ---------------------------------------------------------------------------
# boxed parameters: value + logical axes travel together through init
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class Boxed:
    """A parameter leaf annotated with logical axis names."""
    value: Any
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return ((self.value,), self.axes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def box(value, *axes) -> Boxed:
    return Boxed(value, tuple(axes))


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Strip Boxed wrappers → plain array pytree."""
    return jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)


def boxed_axes(tree):
    """Same-structure pytree of logical-axes tuples."""
    return jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)


def param_pspecs(tree, mesh: Mesh):
    """PartitionSpec pytree for a Boxed param tree on ``mesh``."""
    names = mesh.axis_names
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(b: Boxed):
        v = b.value
        return pspec(v.shape, b.axes, names, shape)

    return jax.tree.map(one, tree, is_leaf=is_boxed)


def param_shardings(tree, mesh: Mesh):
    specs = param_pspecs(tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op off-mesh."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or not mesh.axis_names:
        return x
    shape = dict(zip(mesh.axis_names, mesh.axis_sizes))
    spec = pspec(x.shape, axes, mesh.axis_names, shape)
    return jax.lax.with_sharding_constraint(x, spec)
