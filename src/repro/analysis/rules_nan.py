"""nan-hazard: keep non-finite values out of shared while_loop carries.

The fleet's lockstep ``while_loop``s (L-BFGS-B, line search, MSO tail)
advance *every* slot row each round; the benign-row invariant (ROADMAP:
``_FAR`` idle pattern) only holds if no carry leaf can turn NaN/Inf —
one poisoned row stalls or corrupts the whole block.  Scope: functions
in the while-loop closure (bodies/conds handed to ``lax.while_loop`` /
``scan`` / ``fori_loop`` plus their callees).  Flagged:

* non-finite literals (``jnp.inf`` / ``np.inf`` / ``float("inf")`` /
  ``nan``) outside masking contexts — comparisons, ``jnp.where``,
  ``isfinite``/``isnan``, ``nan_to_num`` keep the sentinel out of the
  carry; a bare literal flowing into arithmetic does not;
* divisions whose denominator is a plain variable (no ``jnp.maximum`` /
  ``jnp.where`` / eps guard): 0/0 in a *frozen* row still propagates
  through the shared carry even when masked later.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .core import (Finding, ModuleInfo, Project, Rule, ancestors,
                   call_target, dotted_name)

# call targets that neutralize a non-finite sentinel or guard a division
MASKING_CALLS = {"where", "isfinite", "isnan", "isinf", "isposinf",
                 "isneginf", "nan_to_num", "clip", "minimum", "maximum",
                 "select", "nanmin", "nanmax", "nan_to_num"}


def _is_nonfinite_literal(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and node.attr in ("inf", "nan"):
        return dotted_name(node) or node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, float):
        if node.value != node.value:
            return "nan"
        if node.value in (float("inf"), float("-inf")):
            return "inf"
    if isinstance(node, ast.Call) and call_target(node) == "float" \
            and node.args and isinstance(node.args[0], ast.Constant) \
            and str(node.args[0].value).lstrip("+-").lower() in (
                "inf", "infinity", "nan"):
        return f'float("{node.args[0].value}")'
    return None


def _in_masking_context(node: ast.AST) -> bool:
    for anc in ancestors(node):
        if isinstance(anc, ast.Compare):
            return True
        if isinstance(anc, ast.Call) and call_target(anc) in MASKING_CALLS:
            return True
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            break
    return False


def _guarded_denominator(node: ast.AST) -> bool:
    """A denominator that cannot be (exactly) zero: guarded by
    maximum/where/clip, offset by a positive literal, or itself a
    literal."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Call) and call_target(node) in MASKING_CALLS:
        return True
    if isinstance(node, ast.Call) and call_target(node) in (
            "sqrt", "exp", "maximum", "float"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.Add,
                                                            ast.Mult)):
        return _guarded_denominator(node.left) \
            or _guarded_denominator(node.right)
    if isinstance(node, ast.UnaryOp):
        return _guarded_denominator(node.operand)
    return False


class NanHazardRule(Rule):
    id = "nan-hazard"
    severity = "warning"
    doc = ("no unmasked non-finite literals or unguarded divisions in "
           "while_loop carry code (the _FAR benign-row invariant)")

    def run(self, module: ModuleInfo, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            if not project.in_while_closure(node):
                continue
            fi = project.func_for_node(node)
            qual = fi.qualname if fi else getattr(node, "name", "<lambda>")
            # local name → assigned value, so a denominator guarded at its
            # definition site (``denom = jnp.maximum(...)``) passes
            assigns = {}
            for n in ast.walk(node):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                        and isinstance(n.targets[0], ast.Name):
                    assigns[n.targets[0].id] = n.value
            for n in ast.walk(node):
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and n is not node \
                        and project.in_while_closure(n):
                    continue       # reported under its own pass
                lit = _is_nonfinite_literal(n)
                if lit is not None and not _in_masking_context(n):
                    par = getattr(n, "_parent", None)
                    if _is_nonfinite_literal(par) if par else False:
                        continue
                    findings.append(module.finding(
                        self, n,
                        f"non-finite literal {lit} outside a masking "
                        f"context may flow into a shared while_loop "
                        f"carry", func=qual))
                elif isinstance(n, ast.BinOp) and isinstance(n.op, ast.Div):
                    den = n.right
                    base = den.value if isinstance(
                        den, ast.Subscript) else den
                    if isinstance(base, ast.Name) and base.id in assigns:
                        den = assigns[base.id]
                    if not _guarded_denominator(den):
                        findings.append(module.finding(
                            self, n,
                            f"division by unguarded value "
                            f"`{dotted_name(n.right) or 'expr'}` in "
                            f"while_loop carry code; clamp with "
                            f"jnp.maximum/where before dividing",
                            func=qual))
        return findings
