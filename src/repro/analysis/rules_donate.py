"""use-after-donate: never read a buffer after donating it to XLA.

``donate_argnums`` hands the argument's device buffer to the compiled
program for in-place reuse; after the call the Python reference points
at freed storage (on non-CPU backends — CPU masks the bug, which is
exactly why it needs a static rule).  The contract (ROADMAP: fused-ask
invariants) is *rebind from the return, then read*.

Detection is two-pass per module: pass 1 collects every name bound to a
``CountingJit(..., donate_argnums=(...))`` (or ``jax.jit`` equivalent)
with literal argnums; pass 2 scans each function for calls through those
names, taints the donated-position arguments that are plain name/
attribute paths, and flags any later *load* of a tainted path that is
not preceded by a rebinding store (line-ordered within the function —
a deliberate approximation: journal-grade precision is not needed to
catch the realistic "kept using self._chol after the fused call" slip).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from .core import (Finding, ModuleInfo, Project, Rule, call_target,
                   const_int_tuple, dotted_name, keyword_arg)


def _donating_assignments(module: ModuleInfo) -> Dict[str, Tuple[int, ...]]:
    """last-segment target name → donated positions."""
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not isinstance(call, ast.Call):
            continue
        if call_target(call) not in ("CountingJit", "jit"):
            continue
        kw = keyword_arg(call, "donate_argnums")
        if kw is None:
            continue
        nums = const_int_tuple(kw)
        if not nums:
            continue
        for t in node.targets:
            name = t.attr if isinstance(t, ast.Attribute) else (
                t.id if isinstance(t, ast.Name) else None)
            if name:
                out[name] = nums
    return out


class UseAfterDonateRule(Rule):
    id = "use-after-donate"
    severity = "error"
    doc = ("arguments at donate_argnums positions must be rebound from "
           "the program's return before any further read")

    def run(self, module: ModuleInfo, project: Project) -> List[Finding]:
        registry = _donating_assignments(module)
        if not registry:
            return []
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            fi = project.func_for_node(node)
            qual = fi.qualname if fi else node.name
            self._check_function(node, registry, module, qual, findings)
        return findings

    def _check_function(self, fn, registry, module: ModuleInfo, qual: str,
                        findings: List[Finding]) -> None:
        # (call line, call end line, jit name, donated path)
        donations: List[Tuple[int, int, str, str]] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_target(node)
            if name not in registry:
                continue
            end = getattr(node, "end_lineno", None) or node.lineno
            for pos in registry[name]:
                if pos >= len(node.args):
                    continue
                path = dotted_name(node.args[pos])
                if path is None:
                    continue   # inline expression: nothing to reuse later
                donations.append((node.lineno, end, name, path))
        if not donations:
            return

        loads: Dict[str, List[int]] = {}
        stores: Dict[str, List[int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.Name, ast.Attribute)):
                path = dotted_name(node)
                if path is None:
                    continue
                ctx = getattr(node, "ctx", None)
                if isinstance(ctx, ast.Store):
                    stores.setdefault(path, []).append(node.lineno)
                elif isinstance(ctx, ast.Load):
                    # an Attribute load that is merely the spine of a
                    # stored attribute (self._chol in ``self._chol = ..``)
                    # carries Store ctx on the outer node only; the inner
                    # Name is Load.  dotted_name() on the outer node
                    # already covered it, so only record maximal chains.
                    par = getattr(node, "_parent", None)
                    if isinstance(par, ast.Attribute):
                        continue
                    loads.setdefault(path, []).append(node.lineno)
        for call_line, call_end, jit_name, path in donations:
            rebinds = [ln for ln in stores.get(path, ()) if ln >= call_line]
            first_rebind = min(rebinds) if rebinds else None
            for ln in loads.get(path, ()):
                if ln <= call_end:
                    continue
                if first_rebind is not None and ln >= first_rebind:
                    continue
                findings.append(Finding(
                    rule=self.id, file=module.rel, line=ln,
                    severity=self.severity,
                    message=(f"read of {path} after it was donated to "
                             f"{jit_name} (line {call_line}) without "
                             f"rebinding from the return"),
                    func=qual, snippet=module.line_text(ln)))
