"""Pallas-kernel benchmark: interpret-mode correctness vs ref.py oracles +
XLA-path timing (CPU; TPU timings require real hardware — the dry-run
covers the structural side there).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash.kernel import flash_attention
from repro.kernels.flash.ref import attention_ref
from repro.kernels.kvp.kernel import kvp
from repro.kernels.kvp.ref import kvp_ref
from repro.kernels.matern.kernel import matern52_gram
from repro.kernels.matern.ref import matern52_gram_ref


def _time(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / reps


def main(full=False):
    key = jax.random.PRNGKey(0)
    rows = []

    # matern gram
    n, d = (512, 20)
    k1, k2, k3 = jax.random.split(key, 3)
    x1 = jax.random.normal(k1, (n, d), jnp.float32)
    x2 = jax.random.normal(k2, (n, d), jnp.float32)
    ils = jnp.exp(jax.random.normal(k3, (d,), jnp.float32) * 0.3)
    amp = jnp.asarray(1.5, jnp.float32)
    err = float(jnp.max(jnp.abs(
        matern52_gram(x1, x2, ils, amp, interpret=True)
        - matern52_gram_ref(x1, x2, ils, amp))))
    us = _time(jax.jit(matern52_gram_ref), x1, x2, ils, amp)
    rows.append(("matern_gram_ref_xla", us, f"interp_err={err:.1e}"))

    # kvp
    al = jax.random.normal(k3, (n,), jnp.float32)
    err = float(jnp.max(jnp.abs(
        kvp(x1, x2, al, ils, amp, interpret=True)
        - kvp_ref(x1, x2, al, ils, amp))))
    us = _time(jax.jit(kvp_ref), x1, x2, al, ils, amp)
    rows.append(("kvp_ref_xla", us, f"interp_err={err:.1e}"))

    # flash attention
    s, h = (512, 64)
    q = jax.random.normal(k1, (s, h), jnp.float32)
    kk = jax.random.normal(k2, (s, h), jnp.float32)
    v = jax.random.normal(k3, (s, h), jnp.float32)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, kk, v, causal=True, interpret=True)
        - attention_ref(q, kk, v, causal=True))))
    us = _time(jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True)),
               q, kk, v)
    rows.append(("flash_attn_ref_xla", us, f"interp_err={err:.1e}"))

    for name, us, derived in rows:
        print(f"kernel,{name},{us:.1f}us,{derived}")
    return rows


if __name__ == "__main__":
    main()
