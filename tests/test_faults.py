"""Fault-tolerance tests: journal durability semantics, checkpoint
hygiene, crash-recovery determinism, quarantine / backpressure / drain
behavior under deterministic fault injection (tests/faults.py), and the
Schur-complement exactness fallback."""
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from faults import FaultInjector
from repro.bo.journal import InjectedCrash, StudyJournal
from repro.bo.sampler import FleetSampler, GPSampler
from repro.bo.space import BoxSpace
from repro.ckpt.manager import CheckpointManager
from repro.core.acquisition import logei_acq
from repro.core.lbfgsb import LbfgsbOptions
from repro.core.mso import MsoOptions
from repro.engine import (AskConfig, AskEngine, EvalEngine, FleetConfig,
                          FleetEngine, FleetFullError, FleetStudyError)
from repro.gp.fit import incremental_update, standardize_masked
from repro.gp.kernels import KernelParams, gram

_MSO = MsoOptions(maxiter=40, pgtol=1e-2)


def _sphere(x):
    return float(np.sum((x - 0.4) ** 2))


def _fleet_kw(**over):
    kw = dict(n_startup_trials=4, n_restarts=4, pad_multiple=8, slots=4,
              posterior_backend="xla", refit_interval=1, warm_start=False,
              mso_options=MsoOptions(**vars(_MSO)))
    kw.update(over)
    return kw


def _drive(fs, rounds):
    for _ in range(rounds):
        for i, t in enumerate(fs.ask_all()):
            fs.tell(i, t.trial_id, _sphere(t.x))


def _journal_records(d):
    path = os.path.join(d, "journal.log")
    return StudyJournal._scan_and_truncate(path, truncate=False)[0]


# ============================================================ journal
def test_journal_roundtrip_and_reopen(tmp_path):
    d = str(tmp_path)
    j = StudyJournal(d)
    for i in range(5):
        assert j.append({"op": "ask", "i": i}) == i
    j.close()
    with pytest.raises(ValueError, match="closed"):
        j.append({"op": "ask"})
    j2 = StudyJournal(d)                 # reopen continues the sequence
    assert j2.seq == 5
    assert j2.truncated_bytes == 0
    assert j2.append({"op": "tell"}) == 5
    recs = j2.replay()
    assert [r["seq"] for r in recs] == list(range(6))
    assert recs[3] == {"seq": 3, "op": "ask", "i": 3}
    j2.close()


def test_journal_truncates_torn_tail(tmp_path):
    """A partial last line (crash mid-append) is dropped at open, and the
    next append reuses its sequence number — the torn record must look
    like it never happened."""
    d = str(tmp_path)
    j = StudyJournal(d)
    for i in range(4):
        j.append({"op": "ask", "i": i})
    j.close()
    with open(j.path, "ab") as f:        # torn write: no newline, half crc
        f.write(b"deadbeef {\"seq\": 4, \"op\"")
    with pytest.warns(UserWarning, match="dropping"):
        j2 = StudyJournal(d)
    assert j2.seq == 4 and j2.truncated_bytes > 0
    assert j2.append({"op": "ask", "i": 4}) == 4
    assert len(j2.replay()) == 5
    j2.close()


def test_journal_crc_corruption_truncates_from_there(tmp_path):
    """A flipped byte mid-file invalidates that record AND everything
    after it (a rewound sequence is indistinguishable from tampering)."""
    d = str(tmp_path)
    j = StudyJournal(d)
    for i in range(6):
        j.append({"op": "ask", "i": i})
    j.close()
    with open(j.path, "rb") as f:
        lines = f.readlines()
    lines[3] = lines[3].replace(b'"i":3', b'"i":9')   # payload vs crc
    with open(j.path, "wb") as f:
        f.writelines(lines)
    with pytest.warns(UserWarning, match="dropping"):
        j2 = StudyJournal(d)
    assert j2.seq == 3                   # records 0..2 survive, 3..5 drop
    assert [r["i"] for r in j2.replay()] == [0, 1, 2]
    j2.close()


def test_injected_crash_leaves_torn_record(tmp_path):
    d = str(tmp_path)
    j = StudyJournal(d, fault_injector=FaultInjector(kill_at_seq=2))
    j.append({"op": "a"})
    j.append({"op": "b"})
    with pytest.raises(InjectedCrash):
        j.append({"op": "c"})
    with pytest.warns(UserWarning, match="dropping"):
        j2 = StudyJournal(d)             # exactly a real kill's aftermath
    assert j2.seq == 2 and j2.truncated_bytes > 0
    j2.close()


# ========================================================= checkpoints
def test_ckpt_dtype_mismatch_refused(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"x": jnp.ones(3, jnp.float64)}, block=True)
    with pytest.raises(ValueError, match="dtype mismatch"):
        mgr.restore(1, {"x": jnp.ones(3, jnp.float32)})


def test_ckpt_latest_step_skips_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save_flat(1, {"x": np.ones(3)})
    mgr.save_flat(2, {"x": np.ones(3)})
    with open(mgr._path(2), "wb") as f:
        f.write(b"not a zip archive")
    with pytest.warns(UserWarning, match="corrupt"):
        assert mgr.latest_step() == 1


def test_ckpt_tmp_files_cleaned_on_init(tmp_path):
    d = str(tmp_path)
    leftover = os.path.join(d, ".tmp_7_999")
    os.makedirs(d, exist_ok=True)
    open(leftover, "w").write("dead writer")
    CheckpointManager(d)
    assert not os.path.exists(leftover)


def test_ckpt_flat_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    flat = {"a": np.arange(6, dtype=np.float64).reshape(2, 3),
            "b": np.asarray(7, np.int64),
            "c": np.asarray(json.dumps(["x", None]))}
    mgr.save_flat(3, flat)
    out = mgr.load_flat(3)
    np.testing.assert_array_equal(out["a"], flat["a"])
    assert int(out["b"]) == 7
    assert json.loads(str(out["c"])) == ["x", None]


# ================================================== tell() guardrails
def test_tell_nonfinite_raises_and_failed_never_enters_gp():
    s = GPSampler(BoxSpace.cube(2, 0.0, 1.0), strategy="dbe_vec",
                  n_startup_trials=4, seed=0)
    t0, t1 = s.ask(), s.ask()
    with pytest.raises(ValueError, match=rf"trial {t0.trial_id}.*failed"):
        s.tell(t0.trial_id, float("nan"))
    assert s.trials[t0.trial_id].state == "pending"   # refused, unchanged
    s.tell(t0.trial_id, 1.0)
    s.tell(t1.trial_id, float("inf"), failed=True, error="diverged")
    X, y = s._observations()
    assert X.shape[0] == 1 and np.all(np.isfinite(y))
    assert s.trials[t1.trial_id].state == "failed"


def test_fleet_tell_nonfinite_refused_before_journal(tmp_path):
    d = str(tmp_path)
    fs = FleetSampler([BoxSpace.cube(2, 0.0, 1.0)], journal_dir=d,
                      **_fleet_kw())
    t = fs.ask_all()[0]                  # startup: random, no compiles
    with pytest.raises(ValueError, match="failed=True"):
        fs.tell(0, t.trial_id, float("-inf"))
    assert _journal_records(d)[-1]["op"] == "ask"     # never acknowledged
    fs.tell(0, t.trial_id, 0.0, failed=True, error="boom")
    last = _journal_records(d)[-1]
    assert last["op"] == "tell" and last["failed"] and last["y"] is None
    # the engine-level guardrail backs the sampler one up
    with pytest.raises(ValueError, match="failed=True"):
        fs.fleet.observe(0, np.full(2, 0.5), float("nan"), tag=9)


# ================================================ backpressure / shed
def test_admission_backpressure_rejects_with_reason():
    eng = FleetEngine(EvalEngine(logei_acq),
                      FleetConfig(dim=2, n_restarts=4, max_studies=1))
    eng.add_study("a")
    with pytest.raises(FleetFullError, match="max_studies=1"):
        eng.add_study("b")
    eng2 = FleetEngine(EvalEngine(logei_acq),
                       FleetConfig(dim=2, n_restarts=4, max_queue=1))
    eng2.add_study("a")
    with pytest.raises(FleetFullError, match="queue full"):
        eng2.add_study("b")
    assert eng.stats_snapshot()["n_rejected"] == 1


def test_fleet_sampler_degrades_to_solo_on_rejection():
    sp = BoxSpace.cube(2, 0.0, 1.0)
    with pytest.raises(FleetFullError):
        FleetSampler([sp] * 3, max_studies=2, **_fleet_kw())
    fs = FleetSampler([sp] * 3, max_studies=2, degrade_to_solo=True,
                      **_fleet_kw())
    assert len(fs) == 3
    degraded = [s for s in fs.samplers if s.degraded is not None]
    assert len(degraded) == 1 and degraded[0]._fleet is None
    snap = fs.stats_snapshot()
    assert snap["n_rejected"] == 1 and snap["n_degraded"] == 1


def test_admission_deadline_load_shed():
    eng = FleetEngine(EvalEngine(logei_acq),
                      FleetConfig(dim=2, n_restarts=4, slots=2,
                                  pad_bucket=8, max_blocks=1))
    for sid in ("a", "b"):               # fill the only block's 2 slots
        eng.add_study(sid)
        eng.observe(sid, np.full(2, 0.5), 1.0)
    eng.step()
    eng.add_study("c", deadline=time.monotonic() - 1.0)   # already late
    eng.observe("c", np.full(2, 0.5), 1.0)
    eng.add_study("d", deadline=time.monotonic() + 60.0)  # can wait
    eng.observe("d", np.full(2, 0.5), 1.0)
    eng.step()
    assert eng.study_state("c")[0] == "shed"
    assert eng.study_state("d")[0] == "queued"
    with pytest.raises(FleetStudyError, match="shed"):
        eng.request_suggest("c")
    assert eng.stats_snapshot()["n_shed"] == 1


# ===================================================== Schur fallback
def test_incremental_update_genuine_ill_conditioned_schur():
    """A duplicate point at (near-)zero noise makes the rank-one Schur
    complement numerically impossible: ok must flip False (and stays True
    for a well-separated append at the same θ)."""
    rng = np.random.default_rng(0)
    b, D, n0 = 8, 2, 5
    p = KernelParams(log_lengthscale=jnp.zeros((D,)),
                     log_amplitude=jnp.asarray(0.0),
                     log_noise=jnp.asarray(-35.0))   # σ_n² ≈ 6e-16
    x = jnp.asarray(rng.uniform(0, 1, (b, D)))
    yv = jnp.asarray(np.sin(3 * np.asarray(x)).sum(1))
    v = jnp.arange(b) < n0
    K = gram(x, p, "matern52", jitter=0.0)
    K = jnp.where(v[:, None] & v[None, :], K, jnp.eye(b))
    chol = jnp.linalg.cholesky(K)
    ys, _, _ = standardize_masked(yv * v, v)
    # well-separated appended point: healthy
    _, _, _, ok = incremental_update(x, ys, jnp.asarray(n0 + 1), p, chol,
                                     jitter=0.0)
    assert bool(ok)
    # duplicate of an existing row: Schur complement ≈ σ_n² → refused
    x_dup = x.at[n0].set(x[2])
    _, _, _, ok = incremental_update(x_dup, ys, jnp.asarray(n0 + 1), p,
                                     chol, jitter=0.0)
    assert not bool(ok)


def test_injected_fallback_matches_scheduled_full_refit():
    """Vetoing the incremental ok (exactness fallback) must reproduce a
    refit_interval=1 engine bit-for-bit — the fallback IS a full refit —
    and the fallback shows up in EngineStats."""
    rng = np.random.default_rng(2)
    D = 3
    mso = LbfgsbOptions(maxiter=40, pgtol=1e-2)
    inj = FaultInjector(incr_fail={None: 999})
    a = AskEngine(EvalEngine(logei_acq),
                  AskConfig(dim=D, n_restarts=4, pad_bucket=8,
                            refit_interval=8, warm_start=False, mso=mso),
                  fault_injector=inj)
    b = AskEngine(EvalEngine(logei_acq),
                  AskConfig(dim=D, n_restarts=4, pad_bucket=8,
                            refit_interval=1, warm_start=False, mso=mso))
    for i in range(5):
        xi = rng.uniform(0, 1, D)
        a.observe(xi, _sphere(xi))
        b.observe(xi, _sphere(xi))
    kinds = []
    for t in range(4):
        key = jax.random.fold_in(jax.random.PRNGKey(0), t)
        bxa, ia = a.suggest(key, fit_seed=t)
        bxb, _ = b.suggest(key, fit_seed=t)
        np.testing.assert_array_equal(bxa, bxb, err_msg=f"trial {t}")
        kinds.append(ia.kind)
        xn = np.clip(bxa, 0, 1)
        a.observe(xn, _sphere(xn))
        b.observe(xn, _sphere(xn))
    assert kinds[0] == "full" and kinds[1:] == ["fallback"] * 3
    assert a.n_fallbacks == 3 and a.n_incremental == 0
    assert a.engine.stats_snapshot()["n_refit_fallbacks"] == 3
    assert inj.n_incr_vetoed == 3


# ============================================== crash recovery (chaos)
def test_crash_recovery_bitwise_per_study_trajectories(tmp_path):
    """Kill the process (injected) at a journal offset mid-run; recover;
    per-study suggestion sequences must match an uninterrupted twin
    bit-for-bit in the cold-refit regime (refit_interval=1, no warm
    start), including across a post-recovery bucket migration."""
    d = str(tmp_path)
    sp = BoxSpace.cube(3, 0.0, 1.0)
    kw = _fleet_kw()
    rounds = 12                          # n crosses the 8→16 bucket at 9
    ref = FleetSampler([sp] * 2, seed=0, **kw)
    _drive(ref, rounds)

    vic = FleetSampler([sp] * 2, seed=0, journal_dir=d,
                       fault_injector=FaultInjector(kill_at_seq=26), **kw)
    crashed = False
    try:
        for r in range(rounds):
            if r == 3:
                vic.checkpoint()         # replay starts mid-journal
            _drive(vic, 1)
    except InjectedCrash:
        crashed = True
    assert crashed

    with pytest.warns(UserWarning, match="dropping"):
        fs, rep = FleetSampler.recover(d)
    assert rep.truncated_bytes > 0       # the torn record was dropped
    assert rep.snapshot_step is not None and rep.n_replayed > 0
    for i, tid in rep.pending:           # asked-but-never-told: re-eval
        fs.tell(i, tid, _sphere(fs.samplers[i].trials[tid].x))
    done = min(len(s.trials) for s in fs.samplers)
    _drive(fs, rounds - done + 1)
    for i in range(2):
        a, b = ref.samplers[i].trials, fs.samplers[i].trials
        n = min(len(a), len(b))
        assert n >= rounds
        for k in range(n):
            np.testing.assert_array_equal(a[k].x, b[k].x,
                                          err_msg=f"study {i} trial {k}")
    assert fs.stats_snapshot()["n_migrations"] >= 1   # post-recovery


def test_sigterm_drain_checkpoint_and_recover(tmp_path):
    """SIGTERM (via SIGUSR1, same handler) during optimize(): the loop
    finishes its in-flight round, drains (checkpoint + journal + clean
    close), and recover() restores trial state and warm-start θ exactly."""
    d = str(tmp_path)
    sp = BoxSpace.cube(3, 0.0, 1.0)
    fs = FleetSampler([sp] * 2, seed=1, journal_dir=d,
                      **_fleet_kw(warm_start=True))
    flag = fs.install_drain_handler()
    _drive(fs, 6)                        # past startup: θ exists
    theta = {i: np.array(fs.fleet.study_theta(i)) for i in range(2)}
    os.kill(os.getpid(), signal.SIGUSR1)
    assert flag.triggered
    fs.optimize(_sphere, 5)              # drains at the round boundary
    assert fs.journal._f is None         # journal closed cleanly
    recs = _journal_records(d)
    assert recs[-1]["op"] == "drain"
    assert any(r["op"] == "refit" for r in recs)

    fs2, rep = FleetSampler.recover(d)
    assert rep.pending == [] and rep.truncated_bytes == 0
    for i in range(2):
        a, b = fs.samplers[i].trials, fs2.samplers[i].trials
        assert [(t.trial_id, t.state) for t in a] == \
               [(t.trial_id, t.state) for t in b]
        for ta, tb in zip(a, b):
            np.testing.assert_array_equal(ta.x, tb.x)
        # journaled refit θ restored bit-for-bit → post-recovery
        # warm-started refits reproduce the uninterrupted run
        np.testing.assert_array_equal(theta[i],
                                      np.asarray(fs2.fleet.study_theta(i)))


# ========================================== quarantine / park (chaos)
def test_quarantine_keeps_far_invariant_and_compile_economy(tmp_path):
    """An injected unhealthy full refit quarantines the newest
    observation (journaled, owning Trial marked), resets its slot row to
    the benign idle pattern, and the retry reuses the SAME compiled
    programs — no trace keyed on quarantine state."""
    d = str(tmp_path)
    sp = BoxSpace.cube(3, 0.0, 1.0)
    inj = FaultInjector(full_fail={1: 1})
    fs = FleetSampler([sp] * 2, seed=2, journal_dir=d,
                      fault_injector=inj, **_fleet_kw())
    _drive(fs, 7)
    assert inj.n_full_vetoed == 1
    snap = fs.stats_snapshot()
    assert snap["n_quarantined"] == 1 and snap["n_parked"] == 0
    # the poisoned trial is named, in the journal and on the Trial
    q = [r for r in _journal_records(d) if r["op"] == "quarantine"]
    assert len(q) == 1 and q[0]["sid"] == 1
    t = fs.samplers[1].trials[q[0]["trial"]]
    assert t.state == "quarantined" and "unhealthy" in t.error
    # _FAR invariant: rows past the study's live count are idle-benign
    st = fs.fleet._studies[1]
    blk, slot, n = st.block, st.slot, st.n
    np.testing.assert_array_equal(np.asarray(blk.x[slot, n:]),
                                  blk.idle_x[n:])
    np.testing.assert_array_equal(np.asarray(blk.y[slot, n:]),
                                  np.zeros(blk.bucket - n))
    # compile economy: one bucket → ≤3 programs, retries included
    assert snap["n_fleet_compiles"] <= 3
    # the study kept being served after quarantine
    assert len(fs.samplers[1].trials) == len(fs.samplers[0].trials)


def test_park_after_quarantine_exhaustion_degrades_to_solo():
    """Persistent unhealthy refits exhaust the quarantine budget: the
    study is parked, its sampler degrades to the solo path, and the rest
    of the fleet is untouched."""
    sp = BoxSpace.cube(3, 0.0, 1.0)
    inj = FaultInjector(full_fail={1: 99})
    fs = FleetSampler([sp] * 2, seed=3, quarantine_retries=1,
                      fault_injector=inj, **_fleet_kw())
    _drive(fs, 8)
    snap = fs.stats_snapshot()
    assert snap["n_parked"] == 1 and snap["n_quarantined"] == 2
    assert snap["n_degraded"] == 1
    s1 = fs.samplers[1]
    assert s1.degraded is not None and "parked" in s1.degraded
    assert s1._fleet is None
    # both studies kept producing trials every round (study 1 solo)
    assert len(s1.trials) == len(fs.samplers[0].trials) == 8
    assert fs.samplers[0].degraded is None
    fs.samplers[0].best()                # fleet study still serves
