"""Pure-jnp oracle for the fused cross-kernel × vector product."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matern.ref import matern52_gram_ref


def kvp_ref(xq: jax.Array, xt: jax.Array, alpha: jax.Array,
            inv_lengthscale: jax.Array, amplitude: jax.Array) -> jax.Array:
    """GP posterior-mean kernel-vector product: (q,) = k(xq, xt) @ alpha."""
    return matern52_gram_ref(xq, xt, inv_lengthscale, amplitude) @ alpha
