"""GPSampler-style Bayesian-optimization controller (ask/tell).

This is the Optuna-integration analogue the paper ships: each `ask` fits a
Matérn-5/2 GP on the observations, builds LogEI, and runs multi-start
L-BFGS-B with a pluggable MSO strategy (`seq` / `cbe` / `dbe` / `dbe_vec`).

Two suggest pipelines sit behind `ask()`:

* the **host pipeline** (scipy strategies, and `dbe_vec` with
  ``fused=False``): from-scratch `fit_gp` + host restart sampling +
  `maximize_acqf` — one device round trip per stage;
* the **fused pipeline** (default for `dbe_vec`): the whole
  standardize → (incremental or full) refit → restart sampling → lockstep
  MSO → argmax chain runs as ONE compiled device program per GP size
  bucket (`engine/ask.py`), with rank-one GP updates between full refits.

Fault tolerance at the controller level: every suggestion is journaled
before being handed out; `tell` completes it; a crashed/preempted trial is
simply re-suggested on resume (`GPSampler.load`).  The controller is the BO
"control plane" driving the distributed trainer in `examples/hpo_train.py`.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bo.journal import StudyJournal
from repro.bo.space import BoxSpace
from repro.ckpt.manager import CheckpointManager, install_sigterm_handler
from repro.core.acquisition import logei_acq
from repro.core.lbfgsb import LbfgsbOptions
from repro.core.mso import MsoOptions, MsoResult, maximize_acqf
from repro.engine import (AskConfig, AskEngine, EvalEngine, FleetFullError,
                          FleetStudyError, fused_logei_acq, resolve_backend)
from repro.engine.cache import merge_retrace_reports
from repro.gp.fit import (fit_gp, pad_bucket_for, standardize,
                          standardize_masked)
from repro.gp.gpr import with_kinv
from repro.obs import trace as obs


def _standardize_bucketed(y: np.ndarray, pad: int) -> jax.Array:
    """Standardize ``y`` with the moments computed over a pad-bucketed
    masked reduction — bit-identical to the fused ask program's
    ``standardize_masked``, sliced back to the live entries."""
    n = y.shape[0]
    b = pad_bucket_for(n, pad)
    y_pad = jnp.zeros((b,), jnp.asarray(y).dtype).at[:n].set(jnp.asarray(y))
    y_std, _, _ = standardize_masked(y_pad, jnp.arange(b) < n)
    return y_std[:n]


@dataclass
class Trial:
    trial_id: int
    x: np.ndarray
    y: Optional[float] = None
    state: str = "pending"    # pending | complete | failed | quarantined
    ask_time: float = 0.0
    tell_time: float = 0.0
    error: Optional[str] = None      # failure/quarantine reason


@dataclass
class SamplerStats:
    n_gp_fits: int = 0
    fit_time: float = 0.0
    acqf_time: float = 0.0
    acqf_iters: List[float] = field(default_factory=list)
    acqf_rounds: List[int] = field(default_factory=list)
    engine: Optional[dict] = None       # last EvalEngine.stats_snapshot()


class GPSampler:
    """Ask/tell BO over a box space; strategy selects the MSO scheme."""

    def __init__(
        self,
        space: BoxSpace,
        *,
        strategy: str = "dbe",
        n_startup_trials: int = 10,
        n_restarts: int = 10,
        mso_options: Optional[MsoOptions] = None,
        seed: int = 0,
        pad_multiple: int = 32,
        gp_fit_restarts: int = 2,
        posterior_backend: str = "auto",
        fused: Optional[bool] = None,
        refit_interval: int = 8,
        warm_start: bool = True,
    ):
        self.space = space
        self.strategy = strategy
        self.n_startup = n_startup_trials
        self.B = n_restarts
        # fresh per instance: a shared default dataclass would leak option
        # mutations across samplers
        self.mso_options = (mso_options if mso_options is not None
                            else MsoOptions())
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.pad_multiple = pad_multiple
        self.gp_fit_restarts = gp_fit_restarts
        self.posterior_backend = resolve_backend(posterior_backend)
        # fused one-program ask(): default for the device-resident
        # strategy; the scipy strategies drive scipy from the host and
        # cannot run inside one program
        self.fused = (strategy == "dbe_vec") if fused is None else bool(fused)
        if self.fused and strategy != "dbe_vec":
            raise ValueError("fused ask() requires strategy='dbe_vec'; "
                             f"got {strategy!r}")
        self.refit_interval = refit_interval
        self.warm_start = warm_start
        # ONE evaluation engine for the whole BO run: every trial's MSO
        # (any strategy) reuses its shape-bucketed jit caches, so compile
        # counts stay O(log B · #GP-size-buckets), not O(trials)
        self._acq_fn = (logei_acq if self.posterior_backend == "xla"
                        else fused_logei_acq(self.posterior_backend))
        self.engine = EvalEngine(self._acq_fn)
        self._ask: Optional[AskEngine] = None       # fused pipeline state
        self._fleet = None                          # attached FleetEngine
        self._fleet_sid = None                      # our study id in it
        self._observed_ids: set = set()             # trials in the ask GP
        self._base_key = jax.random.PRNGKey(seed)   # restart-point stream
        # rng draws consumed by startup asks — recovery burns this many
        # draws to realign the stream before replaying post-snapshot asks
        self._n_startup_asks = 0
        self.degraded: Optional[str] = None   # left the fleet: why
        self.trials: List[Trial] = []
        self.stats = SamplerStats()
        self.last_mso: Optional[MsoResult] = None
        self.last_ask_info = None        # SuggestInfo of last fused ask

    # ----------------------------------------------------------------- api
    def ask(self) -> Trial:
        n_done = sum(t.state == "complete" for t in self.trials)
        if n_done < self.n_startup:
            x = self.space.sample(self.rng, 1)[0]
            self._n_startup_asks += 1
        else:
            x = self._suggest()
        t = Trial(trial_id=len(self.trials), x=x, ask_time=time.time())
        self.trials.append(t)
        return t

    def tell(self, trial_id: int, y: float, *, failed: bool = False,
             error: Optional[str] = None):
        t = self.trials[trial_id]
        if not failed and not np.isfinite(float(y)):
            # guardrail: one NaN/inf flowing into standardization poisons
            # the whole GP (and, in a fleet, the slot block's stacked
            # programs) — refuse loudly, naming the trial
            raise ValueError(
                f"trial {trial_id}: non-finite objective value y={y!r}; "
                f"report evaluation failures with tell(..., failed=True) "
                f"— they never enter GP data")
        t.y = None if failed else float(y)
        t.state = "failed" if failed else "complete"
        t.error = error if failed else None
        t.tell_time = time.time()

    def best(self) -> Trial:
        done = [t for t in self.trials if t.state == "complete"]
        if not done:
            failed = [t for t in self.trials if t.state == "failed"]
            msg = (f"no completed trials to report a best from "
                   f"({len(self.trials)} trials: {len(failed)} failed, "
                   f"{len(self.trials) - len(failed)} pending)")
            errors = [t.error for t in failed if t.error]
            if errors:
                msg += f"; last failure: {errors[-1]}"
            raise RuntimeError(msg)
        return min(done, key=lambda t: t.y)

    def optimize(self, objective, n_trials: int):
        for _ in range(n_trials):
            t = self.ask()
            try:
                self.tell(t.trial_id, objective(t.x))
            except Exception as e:          # noqa: BLE001 — trial isolation
                # keep the run alive but preserve the reason: best() and
                # the journal surface it instead of a silent failed state
                self.tell(t.trial_id, 0.0, failed=True,
                          error=f"{type(e).__name__}: {e}")
        return self.best()

    # -------------------------------------------------------- inner engine
    def _observations(self):
        done = [t for t in self.trials if t.state == "complete"]
        X = np.stack([t.x for t in done])
        y = np.array([t.y for t in done])
        return X, y

    def _suggest(self) -> np.ndarray:
        if self.fused:
            return self._suggest_fused()
        X, y = self._observations()
        U = self.space.to_unit(X)
        # minimize y == maximize -y (standardized)
        t0 = time.perf_counter()
        with obs.span("ask.phase.standardize", n=len(y)):
            if self.strategy == "dbe_vec":
                # run the moments through the same padded masked reduction
                # the fused program uses: reduction shape changes the
                # last-ulp rounding, and the MAP fit amplifies a 1-ulp
                # y_std difference into visibly different hyperparameters
                y_std = _standardize_bucketed(-y, self.pad_multiple)
            else:
                y_std, _, _ = standardize(jnp.asarray(-y))
        with obs.span("ask.phase.refit", n=len(y)):
            gp = fit_gp(jnp.asarray(U), y_std,
                        n_restarts=self.gp_fit_restarts,
                        seed=self.seed + len(self.trials),
                        pad_bucket=self.pad_multiple)
            if self.posterior_backend != "xla":
                gp = with_kinv(gp)  # fused quadratic-form posterior input
        self.stats.n_gp_fits += 1
        self.stats.fit_time += time.perf_counter() - t0

        best_val = jnp.max(y_std)

        # restart points: incumbent + (B-1) uniform (GPSampler-style).
        # dbe_vec draws them from the jax PRNG stream so the unfused path
        # stays trajectory-identical to the fused one-program ask()
        with obs.span("ask.phase.restart_sampling", B=self.B):
            inc = U[int(np.argmin(y))]
            if self.strategy == "dbe_vec":
                rand = np.asarray(jax.random.uniform(
                    self._restart_key(), (self.B - 1, self.space.dim),
                    jnp.asarray(U).dtype))
            else:
                rand = self.rng.uniform(0.0, 1.0,
                                        (self.B - 1, self.space.dim))
            x0 = np.concatenate([inc[None], rand], 0)

        t0 = time.perf_counter()
        with obs.span("ask.phase.lockstep", strategy=self.strategy):
            res = maximize_acqf(self._acq_fn, x0, 0.0, 1.0,
                                acq_state=(gp, best_val),
                                strategy=self.strategy,
                                options=self.mso_options,
                                engine=self.engine)
        self.stats.acqf_time += time.perf_counter() - t0
        self.stats.acqf_iters.append(float(np.median(res.n_iters)))
        self.stats.acqf_rounds.append(res.n_rounds)
        self.stats.engine = res.engine_stats
        self.last_mso = res
        return self.space.from_unit(np.clip(res.best_x, 0.0, 1.0))

    # ------------------------------------------------------- fused path
    def _restart_key(self):
        """Per-trial PRNG key for restart sampling (fused and unfused
        dbe_vec share it — same key ⇒ same restart points)."""
        return jax.random.fold_in(self._base_key, len(self.trials))

    def _suggest_fused(self) -> np.ndarray:
        if self._fleet is not None:
            return self._suggest_fleet()
        done = [t for t in self.trials if t.state == "complete"]
        if self._ask is None:
            o = self.mso_options
            self._ask = AskEngine(self.engine, AskConfig(
                dim=self.space.dim, n_restarts=self.B,
                backend=self.posterior_backend,
                pad_bucket=self.pad_multiple,
                refit_interval=self.refit_interval,
                warm_start=self.warm_start,
                gp_fit_restarts=self.gp_fit_restarts,
                mso=LbfgsbOptions(m=o.m, maxiter=o.maxiter, pgtol=o.pgtol,
                                  ftol=o.ftol, maxls=o.maxls)))
        ask = self._ask
        # lazy observation sync covers tell() and journal resume alike;
        # keyed by trial id, not list position — out-of-order tells must
        # not duplicate/drop observations (the host path rebuilds X, y
        # from scratch each trial and is naturally immune)
        for t in done:
            if t.trial_id not in self._observed_ids:
                ask.observe(self.space.to_unit(t.x), t.y)
                self._observed_ids.add(t.trial_id)

        t0 = time.perf_counter()
        best_x, info = ask.suggest(self._restart_key(),
                                   fit_seed=self.seed + len(self.trials))
        wall = time.perf_counter() - t0
        eng, ak = self.engine.stats_snapshot(), ask.stats_snapshot()
        return self._record_fused_suggest(
            best_x, info, wall,
            {**eng, **ak,
             "retraces": merge_retrace_reports(eng["retraces"],
                                               ak["retraces"])})

    def _record_fused_suggest(self, best_x, info, wall, snapshot):
        """Shared stats tail of the fused/fleet suggest paths.  Per-
        restart state stays on device in both — only the suggestion (and
        scalar diagnostics) ever reach the host."""
        if info.kind != "incremental":
            self.stats.n_gp_fits += 1
        self.stats.acqf_time += wall
        self.stats.acqf_iters.append(
            float(np.median(np.asarray(info.n_iters))))
        self.stats.acqf_rounds.append(int(info.rounds))
        self.stats.engine = snapshot
        self.last_mso = None
        self.last_ask_info = info
        return self.space.from_unit(np.clip(best_x, 0.0, 1.0))

    # ------------------------------------------------------- fleet path
    def attach_fleet(self, fleet, study_id=None) -> "GPSampler":
        """Route this sampler's fused ask() through a shared
        :class:`~repro.engine.fleet.FleetEngine` (one compiled program
        serves every attached study's suggest).

        Must be called before the first trial; the fleet's static config
        must match this sampler's (dim, restarts, bucketing, backend) or
        the stacked programs would not reproduce the solo pipeline.
        Returns ``self`` for chaining.
        """
        if not self.fused:
            raise ValueError("attach_fleet() requires the fused dbe_vec "
                             "pipeline (strategy='dbe_vec', fused=True)")
        if self.trials or self._ask is not None:
            raise ValueError("attach_fleet() must be called before the "
                             "first trial")
        cfg = fleet.cfg
        o = self.mso_options
        mine = dict(dim=self.space.dim, n_restarts=self.B,
                    pad_bucket=self.pad_multiple,
                    backend=self.posterior_backend,
                    refit_interval=self.refit_interval,
                    warm_start=self.warm_start,
                    gp_fit_restarts=self.gp_fit_restarts,
                    mso=(o.m, o.maxiter, o.pgtol, o.ftol, o.maxls))
        theirs = {k: getattr(cfg, k) for k in mine if k != "mso"}
        theirs["mso"] = (cfg.mso.m, cfg.mso.maxiter, cfg.mso.pgtol,
                         cfg.mso.ftol, cfg.mso.maxls)
        if mine != theirs:
            raise ValueError(f"fleet config mismatch: sampler has {mine}, "
                             f"fleet has {theirs}")
        sid = study_id if study_id is not None else f"study-{id(self):x}"
        fleet.add_study(sid)
        self._fleet, self._fleet_sid = fleet, sid
        return self

    def _sync_fleet_observations(self) -> None:
        for t in self.trials:
            if t.state == "complete" and t.trial_id not in self._observed_ids:
                # tag=trial_id: if the fleet later quarantines this
                # observation, the record names the offending trial
                self._fleet.observe(self._fleet_sid,
                                    self.space.to_unit(t.x), t.y,
                                    tag=t.trial_id)
                self._observed_ids.add(t.trial_id)

    def _detach_fleet(self, reason: str) -> None:
        """Graceful degradation: leave the fleet (shed/parked/rejected)
        and continue on the solo fused :class:`AskEngine` path.  A fresh
        ``_observed_ids`` makes the next suggest re-sync every clean
        observation into the (lazily built) solo engine."""
        self._fleet, self._fleet_sid = None, None
        self._observed_ids = set()
        self.degraded = reason

    def mark_quarantined(self, trial_id: int, reason: str) -> None:
        """Record that the fleet quarantined this trial's observation out
        of GP data (numeric poison); the trial keeps its y for audit but
        no longer counts as complete."""
        t = self.trials[trial_id]
        t.state = "quarantined"
        t.error = reason

    def prefetch_suggest(self) -> bool:
        """Enqueue this sampler's next suggest into the attached fleet
        WITHOUT running it — the caller batches many studies' requests
        into one ``fleet.step()`` and then calls ``ask()`` to collect.
        Returns False while the sampler is still in random startup (no
        request enqueued)."""
        if self._fleet is None:
            raise ValueError("no fleet attached")
        n_done = sum(t.state == "complete" for t in self.trials)
        if n_done < self.n_startup:
            return False
        self._sync_fleet_observations()
        try:
            self._fleet.request_suggest(self._fleet_sid,
                                        self._restart_key(),
                                        self.seed + len(self.trials))
        except FleetStudyError as e:
            # shed/parked while we weren't looking: degrade to solo — the
            # next ask() runs the solo fused engine instead
            self._detach_fleet(str(e))
            return False
        return True

    def _suggest_fleet(self) -> np.ndarray:
        self._sync_fleet_observations()
        t0 = time.perf_counter()
        try:
            res = self._fleet.pop_result(self._fleet_sid)
            if res is None:   # solo path: request + step + collect now
                res = self._fleet.suggest(self._fleet_sid,
                                          self._restart_key(),
                                          self.seed + len(self.trials))
        except FleetStudyError as e:
            res = e
        if isinstance(res, FleetStudyError):
            # the fleet shed/parked this study — degrade to the solo
            # engine rather than failing the caller's ask()
            self._detach_fleet(str(res))
            return self._suggest_fused()
        best_x, info = res
        wall = time.perf_counter() - t0
        eng = self._fleet.engine.stats_snapshot()
        flt = self._fleet.stats_snapshot()
        return self._record_fused_suggest(
            best_x, info, wall,
            {**eng, **flt,
             "retraces": merge_retrace_reports(eng["retraces"],
                                               flt["retraces"])})

    # ------------------------------------------------- journal (restart)
    def save(self, path: str):
        rec = {
            "seed": self.seed,
            "strategy": self.strategy,
            "lower": self.space.lower.tolist(),
            "upper": self.space.upper.tolist(),
            "trials": [
                dict(trial_id=t.trial_id, x=t.x.tolist(), y=t.y,
                     state=t.state, error=t.error) for t in self.trials
            ],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, path)        # atomic

    @classmethod
    def load(cls, path: str, **kwargs) -> "GPSampler":
        with open(path) as f:
            rec = json.load(f)
        space = BoxSpace(np.array(rec["lower"]), np.array(rec["upper"]))
        s = cls(space, strategy=rec["strategy"], seed=rec["seed"], **kwargs)
        for tr in rec["trials"]:
            t = Trial(trial_id=tr["trial_id"], x=np.array(tr["x"]),
                      y=tr["y"], state=tr["state"],
                      error=tr.get("error"))
            if t.state == "pending":
                # a trial that never came back (crash/preemption):
                # mark failed; its parameters will be re-explored naturally.
                t.state = "failed"
                t.error = "trial never completed (crash/preemption)"
            s.trials.append(t)
        return s


_TRIAL_STATE = {"pending": 0, "complete": 1, "failed": 2, "quarantined": 3}
_TRIAL_STATE_INV = {v: k for k, v in _TRIAL_STATE.items()}


@dataclass
class RecoveryReport:
    """What :meth:`FleetSampler.recover` reconstructed, and from where."""
    snapshot_step: Optional[int]     # checkpoint the replay started from
    n_records: int                   # intact journal records in total
    n_replayed: int                  # records replayed past the snapshot
    truncated_bytes: int             # torn journal tail dropped at open
    pending: List[Tuple[int, int]]   # (study, trial_id) asked, never told
    replay_ms: float


class FleetSampler:
    """Drive S concurrent BO studies through ONE fleet ask plane.

    One :class:`~repro.engine.fleet.FleetEngine` (and one
    :class:`~repro.engine.EvalEngine`) serves every study: each round,
    all studies' suggest requests are enqueued (`prefetch_suggest`),
    ONE ``fleet.step()`` runs the stacked device programs, and each
    study's :class:`GPSampler` collects its suggestion from the shared
    batch.  Per-study trajectories are bit-for-bit what the same sampler
    would produce solo (same seeds ⇒ same PRNG streams; the fleet's
    masking guarantees slot/batch independence).

    ``spaces`` may be one :class:`BoxSpace` (replicated S times via
    ``n_studies``) or an explicit list; every study shares the static
    fleet config (dim, restarts, bucketing, backend).

    ``mesh`` (optional): a 1-D study mesh
    (:func:`repro.launch.mesh.make_fleet_mesh`).  Slot blocks then hold
    ``slots`` studies PER DEVICE (``slots × ndev`` total), sharded over
    the mesh's study axis, and the fleet programs run under ``shard_map``
    — per-study trajectories stay bit-for-bit identical to any other
    placement, including no mesh at all.

    ``journal_dir`` (optional) turns on the durability plane: every ask
    and tell is written (fsync'd, checksummed) to a
    :class:`~repro.bo.journal.StudyJournal` BEFORE it takes effect, and
    :meth:`checkpoint` snapshots bound how much of it
    :meth:`recover` has to replay after a crash.  ``max_studies`` /
    ``max_queue`` / ``max_blocks`` / ``admission_timeout`` bound
    admission (backpressure); with ``degrade_to_solo=True`` a rejected,
    shed, or parked study degrades to the solo :class:`AskEngine` path
    instead of erroring.  ``fault_injector`` (tests/faults.py) hooks the
    journal and the fleet's refit health flags for deterministic chaos.
    """

    def __init__(
        self,
        spaces,
        *,
        n_studies: Optional[int] = None,
        seed: int = 0,
        slots: int = 8,
        strategy: str = "dbe_vec",
        n_startup_trials: int = 10,
        n_restarts: int = 10,
        mso_options: Optional[MsoOptions] = None,
        pad_multiple: int = 32,
        gp_fit_restarts: int = 2,
        posterior_backend: str = "auto",
        refit_interval: int = 8,
        warm_start: bool = True,
        mesh=None,
        journal_dir: Optional[str] = None,
        fault_injector=None,
        max_studies: Optional[int] = None,
        max_queue: Optional[int] = None,
        max_blocks: Optional[int] = None,
        admission_timeout: Optional[float] = None,
        quarantine_retries: int = 2,
        retry_backoff_base: float = 0.0,
        retry_backoff_cap: float = 2.0,
        retry_backoff_jitter: float = 0.25,
        degrade_to_solo: bool = False,
        sleep_fn=None,
        _journal: Optional[StudyJournal] = None,
    ):
        from repro.engine import FleetConfig, FleetEngine
        from repro.core.lbfgsb import LbfgsbOptions

        if strategy != "dbe_vec":
            raise ValueError("FleetSampler requires strategy='dbe_vec'")
        if isinstance(spaces, BoxSpace):
            spaces = [spaces] * int(n_studies if n_studies else 1)
        dims = {sp.dim for sp in spaces}
        if len(dims) != 1:
            raise ValueError(f"all studies must share one dim, got {dims}")
        backend = resolve_backend(posterior_backend)
        o = mso_options if mso_options is not None else MsoOptions()
        # ------------------------------------------------ durability plane
        self.fault_injector = fault_injector
        self._preempt = None
        if _journal is not None:         # recover(): reuse the open journal
            self.journal: Optional[StudyJournal] = _journal
            journal_dir = _journal.dir
        elif journal_dir is not None:
            self.journal = StudyJournal(journal_dir,
                                        fault_injector=fault_injector)
        else:
            self.journal = None
        self.ckpt = (CheckpointManager(os.path.join(journal_dir, "ckpt"),
                                       async_save=False)
                     if journal_dir is not None else None)
        if self.journal is not None and self.journal.seq == 0:
            # record 0 pins everything recover() needs to rebuild this
            # fleet in an empty process
            self.journal.append({
                "op": "config",
                "lower": [sp.lower.tolist() for sp in spaces],
                "upper": [sp.upper.tolist() for sp in spaces],
                "seed": seed, "slots": slots,
                "n_startup_trials": n_startup_trials,
                "n_restarts": n_restarts, "pad_multiple": pad_multiple,
                "gp_fit_restarts": gp_fit_restarts,
                "posterior_backend": backend,
                "refit_interval": refit_interval,
                "warm_start": warm_start, "max_studies": max_studies,
                "max_queue": max_queue, "max_blocks": max_blocks,
                "admission_timeout": admission_timeout,
                "quarantine_retries": quarantine_retries,
                "retry_backoff_base": retry_backoff_base,
                "retry_backoff_cap": retry_backoff_cap,
                "retry_backoff_jitter": retry_backoff_jitter,
                "degrade_to_solo": degrade_to_solo,
                "mso": dict(m=o.m, maxiter=o.maxiter, pgtol=o.pgtol,
                            ftol=o.ftol, maxls=o.maxls,
                            bucketed=o.bucketed)})
        # ------------------------------------------------------ ask plane
        acq = logei_acq if backend == "xla" else fused_logei_acq(backend)
        self.engine = EvalEngine(acq)
        self.fleet = FleetEngine(self.engine, FleetConfig(
            dim=dims.pop(), n_restarts=n_restarts, slots=slots,
            backend=backend, pad_bucket=pad_multiple,
            refit_interval=refit_interval, warm_start=warm_start,
            gp_fit_restarts=gp_fit_restarts,
            mso=LbfgsbOptions(m=o.m, maxiter=o.maxiter, pgtol=o.pgtol,
                              ftol=o.ftol, maxls=o.maxls),
            max_studies=max_studies, max_queue=max_queue,
            max_blocks=max_blocks, admission_timeout=admission_timeout,
            quarantine_retries=quarantine_retries,
            retry_backoff_base=retry_backoff_base,
            retry_backoff_cap=retry_backoff_cap,
            retry_backoff_jitter=retry_backoff_jitter), mesh=mesh,
            journal=self.journal, fault_injector=fault_injector,
            sleep_fn=sleep_fn)
        self.fleet.on_quarantine = self._on_quarantine
        self.samplers: List[GPSampler] = []
        for i, sp in enumerate(spaces):
            s = GPSampler(sp, strategy="dbe_vec", fused=True, seed=seed + i,
                          n_startup_trials=n_startup_trials,
                          n_restarts=n_restarts, mso_options=replace(o),
                          pad_multiple=pad_multiple,
                          gp_fit_restarts=gp_fit_restarts,
                          posterior_backend=backend,
                          refit_interval=refit_interval,
                          warm_start=warm_start)
            try:
                s.attach_fleet(self.fleet, study_id=i)
            except FleetFullError as e:
                if not degrade_to_solo:
                    raise
                s.degraded = str(e)       # solo from birth (load shed)
            self.samplers.append(s)

    def __len__(self) -> int:
        return len(self.samplers)

    def _append(self, rec: dict) -> None:
        if self.journal is not None:
            self.journal.append(rec)

    def _on_quarantine(self, sid, tag, reason) -> None:
        if tag is not None:
            self.samplers[sid].mark_quarantined(tag, reason)

    def ask_all(self) -> List[Trial]:
        """One fleet trial boundary: enqueue every study's suggest, run
        ONE batched step, collect per-study trials (startup studies
        sample randomly and skip the batch; degraded studies run their
        solo engine).  Every ask is journaled (WAL) before the trial is
        handed back."""
        out = self.ask_batch(range(len(self.samplers)))
        for t in out:                    # sync semantics: failures raise
            if isinstance(t, Exception):
                raise t
        return out

    def ask_batch(self, studies) -> List:
        """Ask a *subset* of studies at one trial boundary, batched into
        ONE ``fleet.step()`` (the BO service's dispatch plane: only the
        studies the scheduler picked this round pay for a suggest).
        Per-study failures are isolated — the returned list holds the
        exception in that study's position instead of raising, so one
        broken study cannot take down the whole batch."""
        studies = list(studies)
        tr = obs.get()
        t0 = tr.now_us() if tr is not None else 0.0
        for i in studies:
            s = self.samplers[i]
            if s._fleet is not None:
                s.prefetch_suggest()
        self.fleet.step()
        out: List = []
        for i in studies:
            s = self.samplers[i]
            n_done = sum(t.state == "complete" for t in s.trials)
            startup = n_done < s.n_startup
            try:
                t = s.ask()
            except Exception as e:       # noqa: BLE001 — study isolation
                out.append(e)
                continue
            self._append({"op": "ask", "study": i, "trial": t.trial_id,
                          "x": t.x.tolist(), "startup": startup})
            out.append(t)
        if tr is not None:
            tr.record_span("fleet.ask_batch", t0, tr.now_us() - t0,
                           n=len(studies))
        return out

    def cancel_ask(self, study: int) -> bool:
        """Withdraw a study's in-flight fleet suggest (service deadline
        shed): the slot reservation is freed and any uncollected result
        discarded.  Deterministic to undo — suggest keys derive from the
        trial count, so a later re-request recomputes the same point."""
        s = self.samplers[study]
        if s._fleet is None:
            return False
        return self.fleet.cancel_request(s._fleet_sid)

    def tell(self, study: int, trial_id: int, y: float, *,
             failed: bool = False, error: Optional[str] = None) -> None:
        if not failed and not np.isfinite(float(y)):
            # validate BEFORE journaling: a poison value must never be
            # acknowledged into the WAL
            raise ValueError(
                f"study {study} trial {trial_id}: non-finite objective "
                f"value y={y!r}; report evaluation failures with "
                f"failed=True — they never enter GP data")
        self._append({"op": "tell", "study": study, "trial": trial_id,
                      "y": None if failed else float(y), "failed": failed,
                      "error": error})
        self.samplers[study].tell(trial_id, y, failed=failed, error=error)
        fi = self.fault_injector
        if fi is not None and hasattr(fi, "tell_delay"):
            d = fi.tell_delay()     # injected slow tell (virtual clock)
            if d > 0.0:
                self.fleet._sleep(d)

    def optimize(self, objectives, n_rounds: int) -> List[Trial]:
        """Run ``n_rounds`` synchronized ask/tell rounds; ``objectives``
        is one callable (shared) or one per study.  Returns per-study
        best trials.  If :meth:`install_drain_handler` armed a
        preemption flag, a SIGTERM finishes the in-flight round, then
        drains (checkpoint + journal + clean close) and stops early."""
        if callable(objectives):
            objectives = [objectives] * len(self.samplers)
        for _ in range(n_rounds):
            if self._preempt is not None and self._preempt.triggered:
                self.drain()
                break
            trials = self.ask_all()
            for s, t in enumerate(trials):
                try:
                    y = objectives[s](t.x)
                except Exception as e:   # noqa: BLE001 — trial isolation
                    self.tell(s, t.trial_id, 0.0, failed=True,
                              error=f"{type(e).__name__}: {e}")
                    continue
                if np.isfinite(float(y)):
                    self.tell(s, t.trial_id, y)
                else:                    # degrade, don't crash the loop
                    self.tell(s, t.trial_id, 0.0, failed=True,
                              error=f"non-finite objective value {y!r}")
        return [s.best() for s in self.samplers]

    # ------------------------------------------------- durability plane
    def checkpoint(self) -> int:
        """Snapshot every study's trial history (plus warm-start θ)
        through the CheckpointManager — bounds how much journal
        :meth:`recover` replays.  Returns the snapshot step, which IS
        the journal seq watermark: records with ``seq >=`` it are
        post-snapshot."""
        if self.ckpt is None:
            raise ValueError("checkpoint() needs journal_dir")
        step = self.journal.seq
        flat: Dict[str, np.ndarray] = {
            "seq": np.asarray(step, np.int64),
            "n_studies": np.asarray(len(self.samplers), np.int64),
        }
        for i, s in enumerate(self.samplers):
            flat[f"s{i}/x"] = (np.stack([t.x for t in s.trials])
                               if s.trials
                               else np.zeros((0, s.space.dim)))
            flat[f"s{i}/y"] = np.asarray(
                [np.nan if t.y is None else t.y for t in s.trials],
                np.float64)
            flat[f"s{i}/state"] = np.asarray(
                [_TRIAL_STATE[t.state] for t in s.trials], np.int64)
            flat[f"s{i}/error_json"] = np.asarray(
                json.dumps([t.error for t in s.trials]))
            flat[f"s{i}/n_startup_asks"] = np.asarray(
                s._n_startup_asks, np.int64)
            if s._fleet is not None:
                th = self.fleet.study_theta(s._fleet_sid)
                if th is not None:
                    flat[f"s{i}/theta"] = th
        self.ckpt.save_flat(step, flat)
        self._append({"op": "snapshot", "step": step})
        obs.instant("fleet.checkpoint", step=step)
        return step

    def install_drain_handler(self):
        """Arm SIGTERM/SIGUSR1 → returns the
        :class:`~repro.ckpt.manager.PreemptionFlag`.  :meth:`optimize`
        polls it at round boundaries; external drivers poll
        ``flag.triggered`` and call :meth:`drain` themselves."""
        self._preempt = install_sigterm_handler()
        return self._preempt

    def drain(self) -> dict:
        """Graceful shutdown: serve the suggests already enqueued
        (finish in-flight work, admit nothing new), checkpoint the full
        study state, journal a drain record, close the journal.  After
        ``drain()`` the journal directory is a complete, recoverable
        image of the fleet."""
        with obs.span("fleet.drain"):
            served = self.fleet.step()
            step = None
            if self.ckpt is not None:
                step = self.checkpoint()
            if self.journal is not None:
                self._append({"op": "drain", "served": served,
                              "snapshot": step})
                self.journal.close()
        return {"served": served, "snapshot_step": step}

    @classmethod
    def recover(cls, journal_dir: str, *, mesh=None, fault_injector=None,
                sleep_fn=None) -> Tuple["FleetSampler", RecoveryReport]:
        """Reconstruct a crashed/drained fleet from its journal directory.

        The config record rebuilds the fleet; the newest valid snapshot
        restores bulk trial state (burning one rng draw per recorded
        startup ask so the random streams realign); the journal tail
        past the snapshot replays through the NORMAL paths — tells
        re-enter via the standard out-of-order sync at the next
        prefetch, studies re-admit through the slot scheduler, and
        device factors are rebuilt by the first post-recovery full
        refit, exactly like a post-migration suggest — so recovery adds
        NO new compiled programs.  Trials that were asked but never told
        stay pending and are listed in the report for the driver to
        re-evaluate."""
        t0 = time.perf_counter()
        tr_obs = obs.get()
        t_obs = tr_obs.now_us() if tr_obs is not None else 0.0
        journal = StudyJournal(journal_dir, fault_injector=fault_injector)
        records = journal.replay()
        if not records or records[0].get("op") != "config":
            journal.close()
            raise ValueError(
                f"journal at {journal_dir!r} has no config record — "
                f"nothing to recover")
        cfg = records[0]
        spaces = [BoxSpace(np.asarray(lo), np.asarray(up))
                  for lo, up in zip(cfg["lower"], cfg["upper"])]
        defaults = {"retry_backoff_base": 0.0, "retry_backoff_cap": 2.0,
                    "retry_backoff_jitter": 0.25}
        fs = cls(spaces, mesh=mesh, fault_injector=fault_injector,
                 sleep_fn=sleep_fn, _journal=journal,
                 mso_options=MsoOptions(**cfg["mso"]),
                 **{k: cfg.get(k, defaults.get(k)) for k in (
                     "seed", "slots", "n_startup_trials", "n_restarts",
                     "pad_multiple", "gp_fit_restarts",
                     "posterior_backend", "refit_interval", "warm_start",
                     "max_studies", "max_queue", "max_blocks",
                     "admission_timeout", "quarantine_retries",
                     "retry_backoff_base", "retry_backoff_cap",
                     "retry_backoff_jitter", "degrade_to_solo")})
        # ---- snapshot: bulk state, bounding the replay length
        snap_seq, snap_step = 0, None
        if fs.ckpt is not None:
            snap_step = fs.ckpt.latest_step()
        if snap_step is not None:
            flat = fs.ckpt.load_flat(snap_step)
            snap_seq = int(flat["seq"])
            for i, s in enumerate(fs.samplers):
                errors = json.loads(str(flat[f"s{i}/error_json"]))
                xs, ys = flat[f"s{i}/x"], flat[f"s{i}/y"]
                for j, code in enumerate(flat[f"s{i}/state"]):
                    y = float(ys[j])
                    s.trials.append(Trial(
                        trial_id=j, x=np.asarray(xs[j]),
                        y=None if np.isnan(y) else y,
                        state=_TRIAL_STATE_INV[int(code)],
                        error=errors[j]))
                for _ in range(int(flat[f"s{i}/n_startup_asks"])):
                    s.space.sample(s.rng, 1)      # realign the stream
                s._n_startup_asks = int(flat[f"s{i}/n_startup_asks"])
                if f"s{i}/theta" in flat and s._fleet is not None:
                    fs.fleet.restore_theta(s._fleet_sid,
                                           flat[f"s{i}/theta"])
        # ---- replay the journal tail through the normal paths
        n_replayed = 0
        for rec in records:
            if rec["seq"] < snap_seq:
                continue
            n_replayed += 1
            op = rec["op"]
            if op == "ask":
                s = fs.samplers[rec["study"]]
                assert rec["trial"] == len(s.trials), (
                    f"journal gap: study {rec['study']} ask for trial "
                    f"{rec['trial']} but only {len(s.trials)} known")
                if rec["startup"]:
                    s.space.sample(s.rng, 1)      # burn: realign stream
                    s._n_startup_asks += 1
                s.trials.append(Trial(trial_id=rec["trial"],
                                      x=np.asarray(rec["x"])))
            elif op == "tell":
                s = fs.samplers[rec["study"]]
                s.tell(rec["trial"], 0.0 if rec["failed"] else rec["y"],
                       failed=rec["failed"], error=rec.get("error"))
            elif op == "refit":
                s = fs.samplers[rec["sid"]]
                if s._fleet is not None:
                    fs.fleet.restore_theta(s._fleet_sid,
                                           np.asarray(rec["theta"]))
            elif op == "quarantine":
                s = fs.samplers[rec["sid"]]
                if rec.get("trial") is not None:
                    s.mark_quarantined(rec["trial"], rec["reason"])
            elif op in ("shed", "park"):
                s = fs.samplers[rec["sid"]]
                if s._fleet is not None:
                    fs.fleet.shed_study(s._fleet_sid, rec["reason"])
                    s._detach_fleet(rec["reason"])
            # config/snapshot/admit/migrate/reject/drain: informational
        pending = [(i, t.trial_id) for i, s in enumerate(fs.samplers)
                   for t in s.trials if t.state == "pending"]
        report = RecoveryReport(
            snapshot_step=snap_step, n_records=len(records),
            n_replayed=n_replayed,
            truncated_bytes=journal.truncated_bytes, pending=pending,
            replay_ms=1e3 * (time.perf_counter() - t0))
        if tr_obs is not None:
            tr_obs.record_span("fleet.recover", t_obs,
                               tr_obs.now_us() - t_obs,
                               n_records=len(records),
                               n_replayed=n_replayed)
        return fs, report

    def stats_snapshot(self) -> dict:
        eng, flt = self.engine.stats_snapshot(), self.fleet.stats_snapshot()
        snap = {**eng, **flt}
        # both planes report retrace causes; merge rather than shadow
        snap["retraces"] = merge_retrace_reports(eng["retraces"],
                                                 flt["retraces"])
        snap["n_degraded"] = sum(s.degraded is not None
                                 for s in self.samplers)
        if self.journal is not None:
            snap["journal_seq"] = self.journal.seq
        return snap
