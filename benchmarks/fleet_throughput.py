"""Fleet ask throughput: S concurrent studies through one fleet plane vs
a loop of single-study fused AskEngines.

For each fleet size S the same trial schedule runs twice:

* **loop** — S independent `GPSampler(fused=True)` studies served one
  `ask()` at a time (the PR-2 pipeline: already one compiled program per
  suggest, but the device sees B≈10 restarts at a time and every study
  carries its OWN jitted programs — compile cost is O(S · #buckets));
* **fleet** — the same S studies through ONE `FleetSampler`: every
  round, all suggest requests batch into one `fleet.step()` running the
  stacked (S, B, D) programs per slot block; blocks of equal (bucket,
  slots) shape share executables, so compile cost is O(#buckets),
  independent of S.

Two throughput numbers per run:

* **aggregate** (the headline serving metric): S·rounds / total wall
  over ALL post-startup suggest rounds — XLA traces included, because
  admitting a study into the fleet is free while admitting one to the
  loop compiles fresh per-study programs.  This is where the fleet's
  compile economy turns into wall-clock at scale.
* **steady** (the per-trial metric): S / median(round wall) over rounds
  where every study took the incremental O(n²) program and nothing
  traced — PR 2's steady-state definition lifted to the fleet.  On CPU
  the lockstep fleet pays max-study rounds here and roughly breaks even
  with the loop; on wide-vector backends the stacked programs win both.

--check-compiles asserts fleet compile counts ≤ 3 per (bucket, slots)
shape and independent of S, and (xla, S=16 in the sweep) the ≥4×
aggregate speedup acceptance target.  The pallas_interpret backend runs
for correctness/compile accounting only — interpreter-mode emulation of
the vmapped posterior kernel is python-speed, so its wall-clock rows
are not a performance signal.

Emits BENCH_fleet.json.

--mesh N adds fleet_mesh rows: the same fleet with its slot blocks
sharded over 1 and N devices (cfg.slots is the per-device width).
--check-compiles then additionally asserts compile counts do not move
with the device count — the mesh half of the compile-economy invariant.

--chaos adds a kill-and-recover row: the same schedule runs journaled
(``journal_dir``), a fault injector kills the "process" at a journal
offset mid-run (plus one injected unhealthy refit → quarantine),
``FleetSampler.recover`` rebuilds the fleet, and the schedule completes.
Reported: recovery time (journal replay ms per 100 trials — the headline
``summary`` scalar) and goodput under faults (completed suggests per
second of total wall, crash and recovery included).  --check-compiles
then also asserts the recovered fleet stays within the ≤3-traces-per-
(bucket, slots) budget — recovery and quarantine add no programs.

Usage:
  python benchmarks/fleet_throughput.py [--tiny] [--rounds N]
      [--fleet-sizes 1 4 16 64] [--slots K] [--mesh N] [--chaos]
      [--backends xla pallas_interpret ...] [--check-compiles]
      [--out BENCH_fleet.json]
"""
import argparse
import json
import os
import platform
import shutil
import sys
import tempfile
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                     # noqa: E402

from repro.analysis.runtime import install_nan_guard, nan_guard_stats  # noqa: E402
from repro.bo.objectives import make_objective         # noqa: E402
from repro.obs import export as obs_export             # noqa: E402
from repro.obs import trace as obs_trace               # noqa: E402
from repro.bo.sampler import FleetSampler, GPSampler   # noqa: E402
from repro.bo.space import BoxSpace                    # noqa: E402
from repro.core.mso import MsoOptions                  # noqa: E402

SPEEDUP_TARGET_S = 16       # acceptance: >=4x aggregate at S=16 (xla CPU)
SPEEDUP_TARGET = 4.0


def _objectives(S, D, seed=0):
    return [make_objective("sphere", D, seed=seed + i) for i in range(S)]


def _sampler_kw(args, backend):
    return dict(n_startup_trials=args.n_startup, n_restarts=args.B,
                pad_multiple=args.pad, posterior_backend=backend,
                refit_interval=args.refit_interval,
                mso_options=MsoOptions())


def run_loop(S, backend, args):
    """Baseline: S independent fused AskEngine studies, asked in a loop."""
    objs = _objectives(S, args.D)
    samplers = [GPSampler(BoxSpace.cube(args.D, *objs[i].bounds),
                          strategy="dbe_vec", fused=True, seed=i,
                          **_sampler_kw(args, backend))
                for i in range(S)]

    def compiles():
        return sum(s._ask.stats_snapshot()["n_ask_compiles"]
                   for s in samplers if s._ask is not None)

    round_ms, steady = [], []
    for r in range(args.rounds):
        c0 = compiles()
        t0 = time.perf_counter()
        trials = [s.ask() for s in samplers]
        wall = time.perf_counter() - t0
        kinds = [s.last_ask_info.kind if s.last_ask_info is not None
                 else "startup" for s in samplers]
        round_ms.append(1e3 * wall)
        steady.append(all(k == "incremental" for k in kinds)
                      and compiles() == c0)
        for s, t, obj in zip(samplers, trials, objs):
            s.tell(t.trial_id, obj(t.x))
    return round_ms, steady, {"n_compiles_total": compiles()}


def run_fleet(S, backend, args, mesh_devices=None):
    """One FleetSampler serving all S studies per round.

    ``mesh_devices`` shards the fleet's slot blocks over that many
    devices (``cfg.slots`` is the PER-DEVICE width, so the per-device
    slot count shrinks as devices are added and the compiled local
    program stays fixed-width — the placement-independence invariant)."""
    objs = _objectives(S, args.D)
    mesh = None
    slots = min(args.slots, S)
    if mesh_devices is not None:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(mesh_devices)
        slots = max(1, min(args.slots, -(-S // mesh_devices)))
    fs = FleetSampler([BoxSpace.cube(args.D, *o.bounds) for o in objs],
                      seed=0, slots=slots, mesh=mesh,
                      **_sampler_kw(args, backend))
    if args.debug_nans:
        install_nan_guard(fs.fleet)
    round_ms, steady = [], []
    for r in range(args.rounds):
        c0 = fs.stats_snapshot()["n_fleet_compiles"]
        t0 = time.perf_counter()
        trials = fs.ask_all()
        wall = time.perf_counter() - t0
        kinds = [s.last_ask_info.kind if s.last_ask_info is not None
                 else "startup" for s in fs.samplers]
        round_ms.append(1e3 * wall)
        steady.append(all(k == "incremental" for k in kinds)
                      and fs.stats_snapshot()["n_fleet_compiles"] == c0)
        for i, (t, obj) in enumerate(zip(trials, objs)):
            fs.tell(i, t.trial_id, obj(t.x))
    snap = fs.stats_snapshot()
    n_buckets = len({blk.bucket for blk in fs.fleet._blocks})
    extra = {
        "n_buckets": n_buckets,
        "n_blocks": snap["n_blocks"],
        "n_compiles_total": snap["n_fleet_compiles"],
        "n_full_refits": snap["n_full_refits"],
        "n_incremental": snap["n_incremental"],
        "n_fallbacks": snap["n_fallbacks"],
        "n_migrations": snap["n_migrations"],
        "retrace_causes": snap["retraces"]["causes"],
    }
    if args.debug_nans:
        extra["nan_guard"] = nan_guard_stats(fs.fleet)
    if mesh_devices is not None:
        extra.update({
            "mesh_devices": snap["n_devices"],
            "slots_per_device_width": slots,
            "occupancy_per_device": snap["slots_per_device"],
            "n_migrations_intra": snap["n_migrations_intra"],
            "n_migrations_cross": snap["n_migrations_cross"],
        })
    return round_ms, steady, extra


def run_chaos(S, backend, args):
    """Kill-and-recover under fault injection: journaled fleet, one
    injected unhealthy refit (→ quarantine), an injected crash at a
    journal offset, ``FleetSampler.recover``, then the schedule
    completes.  Returns one ``fleet_chaos`` row."""
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "tests"))
    from faults import FaultInjector
    from repro.bo.journal import InjectedCrash

    objs = _objectives(S, args.D)
    spaces = [BoxSpace.cube(args.D, *o.bounds) for o in objs]
    d = tempfile.mkdtemp(prefix="fleet_chaos_")
    # land the kill ~60% through the expected ask+tell record stream
    kill_seq = max(2, int(0.6 * args.rounds * 2 * S))
    inj = FaultInjector(kill_at_seq=kill_seq, full_fail={0: 1})
    fs = FleetSampler(spaces, seed=0, slots=min(args.slots, S),
                      journal_dir=d, fault_injector=inj,
                      **_sampler_kw(args, backend))
    if args.debug_nans:
        install_nan_guard(fs.fleet)
    t0 = time.perf_counter()
    crashed = False
    try:
        for r in range(args.rounds):
            if r == args.n_startup + 1:
                fs.checkpoint()          # bound the replay length
            trials = fs.ask_all()
            for i, (t, obj) in enumerate(zip(trials, objs)):
                fs.tell(i, t.trial_id, obj(t.x))
    except InjectedCrash:
        crashed = True
    wall1 = time.perf_counter() - t0
    if not crashed:
        raise SystemExit(f"--chaos: kill_seq={kill_seq} never reached "
                         f"(rounds={args.rounds} too small)")
    completed_pre = sum(sum(t.state == "complete" for t in s.trials)
                        for s in fs.samplers)

    t0 = time.perf_counter()
    fs2, rep = FleetSampler.recover(d)
    recover_wall = time.perf_counter() - t0
    if args.debug_nans:
        install_nan_guard(fs2.fleet)
    n_at_recovery = sum(len(s.trials) for s in fs2.samplers)
    for i, tid in rep.pending:           # asked-but-never-told: re-eval
        fs2.tell(i, tid, objs[i](fs2.samplers[i].trials[tid].x))
    t0 = time.perf_counter()
    while min(len(s.trials) for s in fs2.samplers) < args.rounds:
        trials = fs2.ask_all()
        for i, (t, obj) in enumerate(zip(trials, objs)):
            fs2.tell(i, t.trial_id, obj(t.x))
    wall2 = time.perf_counter() - t0
    fs2.drain()

    snap = fs2.stats_snapshot()
    n_buckets = len({blk.bucket for blk in fs2.fleet._blocks})
    completed = sum(sum(t.state == "complete" for t in s.trials)
                    for s in fs2.samplers)
    # quarantine survives recovery as trial state (the engine counter is
    # per-process; the journal record is what persists)
    quarantined = sum(sum(t.state == "quarantined" for t in s.trials)
                      for s in fs2.samplers)
    total_wall = wall1 + recover_wall + wall2
    replay_per_100 = 100.0 * rep.replay_ms / max(n_at_recovery, 1)
    # goodput / loss breakdown, field-compatible with benchmarks/
    # bo_serve.py's chaos row: the fleet analog of a deadline miss is a
    # suggest in flight at the kill (asked, never told) — recovery
    # re-evaluates it rather than losing it, so it is counted separately
    # from work that completed cleanly on either side of the crash
    completed_post = completed - completed_pre
    row = {
        "backend": backend, "mode": "fleet_chaos", "S": S,
        "rounds": args.rounds, "D": args.D, "B": args.B,
        "pad": args.pad, "slots": min(args.slots, S),
        "refit_interval": args.refit_interval,
        "n_startup": args.n_startup,
        "kill_seq": kill_seq,
        "snapshot_step": rep.snapshot_step,
        "n_records": rep.n_records,
        "n_replayed": rep.n_replayed,
        "truncated_bytes": rep.truncated_bytes,
        "n_pending_retold": len(rep.pending),
        "n_trials_at_recovery": n_at_recovery,
        "replay_ms": round(rep.replay_ms, 3),
        "recover_wall_ms": round(1e3 * recover_wall, 3),
        "replay_ms_per_100_trials": round(replay_per_100, 3),
        "completed_suggests": completed,
        "goodput_sps": completed / total_wall,
        "goodput_pre_crash_sps": completed_pre / wall1,
        "goodput_post_recovery_sps": (completed_post / wall2
                                      if wall2 > 0 else None),
        "inflight_at_crash": len(rep.pending),
        "deadline_miss": 0,      # the fleet plane has no request deadlines
        "shed": 0,               # nothing is dropped: recovery re-evals
        "n_quarantined": quarantined,
        "n_buckets": n_buckets,
        "n_compiles_total": snap["n_fleet_compiles"],
        "retrace_causes": snap["retraces"]["causes"],
    }
    if args.debug_nans:
        row["nan_guard"] = nan_guard_stats(fs2.fleet)
    print(f"fleet_bench,{backend},S={S},chaos,kill_seq={kill_seq},"
          f"replay={replay_per_100:.2f}ms/100trials,"
          f"goodput={row['goodput_sps']:.2f}/s,"
          f"quarantined={quarantined},"
          f"compiles={snap['n_fleet_compiles']}", flush=True)
    if args.check_compiles:
        assert quarantined >= 1, \
            "chaos: injected unhealthy refit never quarantined"
        assert rep.truncated_bytes > 0, \
            "chaos: injected crash left no torn record"
        assert snap["n_fleet_compiles"] <= 3 * n_buckets, \
            f"chaos: {snap['n_fleet_compiles']} traces for {n_buckets} " \
            f"buckets after recovery (must be <= 3/bucket); " \
            f"retrace causes: {snap['retraces']['by_program']}"
        print(f"fleet_bench,{backend},S={S},chaos compile check OK "
              f"({snap['n_fleet_compiles']} traces, {n_buckets} buckets)",
              flush=True)
    shutil.rmtree(d)
    return row


def _throughputs(S, round_ms, steady, n_startup):
    """(aggregate sps over all post-startup rounds incl. traces,
    steady-state sps, #steady rounds)."""
    post = round_ms[n_startup:]
    agg = S * len(post) / (sum(post) / 1e3) if post else None
    sm = [m for m, keep in zip(round_ms, steady) if keep]
    sps = S / (float(np.median(sm)) / 1e3) if sm else None
    return agg, sps, len(sm)


def bench_backend(backend, sizes, args):
    rows = []
    fleet_compiles = {}
    for S in sizes:
        res = {}
        for mode, runner in (("loop", run_loop), ("fleet", run_fleet)):
            round_ms, steady, extra = runner(S, backend, args)
            agg, sps, n_steady = _throughputs(S, round_ms, steady,
                                              args.n_startup)
            row = {
                "backend": backend, "mode": mode, "S": S,
                "rounds": args.rounds, "D": args.D, "B": args.B,
                "pad": args.pad, "slots": min(args.slots, S),
                "refit_interval": args.refit_interval,
                "n_startup": args.n_startup,
                "round_ms": [round(m, 3) for m in round_ms],
                "suggests_per_sec_aggregate": agg,
                "suggests_per_sec_steady": sps,
                "n_steady_rounds": n_steady,
                **extra,
            }
            rows.append(row)
            res[mode] = row
            sps_s = f"{sps:.2f}/s" if sps else "n/a"
            agg_s = f"{agg:.2f}/s" if agg else "n/a"
            print(f"fleet_bench,{backend},S={S},{mode},"
                  f"aggregate={agg_s},steady={sps_s},"
                  f"compiles={extra['n_compiles_total']}", flush=True)
        lo, fl = res["loop"], res["fleet"]
        speed = None            # rounds <= n_startup: nothing to compare
        if lo["suggests_per_sec_aggregate"] and \
                fl["suggests_per_sec_aggregate"]:
            speed = (fl["suggests_per_sec_aggregate"]
                     / lo["suggests_per_sec_aggregate"])
        speed_steady = None
        if lo["suggests_per_sec_steady"] and fl["suggests_per_sec_steady"]:
            speed_steady = (fl["suggests_per_sec_steady"]
                            / lo["suggests_per_sec_steady"])
        print(f"fleet_bench,{backend},S={S},speedup_aggregate="
              f"{speed if speed else float('nan'):.2f}x,speedup_steady="
              f"{speed_steady if speed_steady else float('nan'):.2f}x",
              flush=True)
        rows.append({"backend": backend, "S": S, "summary": True,
                     "speedup_aggregate": speed,
                     "speedup_steady": speed_steady})
        fleet_compiles[S] = (fl["n_compiles_total"], fl["n_buckets"])
        fleet_retraces = fl["retrace_causes"]

        # mesh rows: the same fleet sharded over 1 and --mesh devices —
        # compile counts must not move with the device count
        if args.mesh and backend == "xla":
            mesh_compiles = {}
            for ndev in sorted({1, args.mesh}):
                round_ms, steady, extra = run_fleet(S, backend, args,
                                                    mesh_devices=ndev)
                agg, sps, n_steady = _throughputs(S, round_ms, steady,
                                                  args.n_startup)
                rows.append({
                    "backend": backend, "mode": "fleet_mesh", "S": S,
                    "rounds": args.rounds, "D": args.D, "B": args.B,
                    "pad": args.pad,
                    "refit_interval": args.refit_interval,
                    "n_startup": args.n_startup,
                    "round_ms": [round(m, 3) for m in round_ms],
                    "suggests_per_sec_aggregate": agg,
                    "suggests_per_sec_steady": sps,
                    "n_steady_rounds": n_steady,
                    **extra,
                })
                mesh_compiles[ndev] = (extra["n_compiles_total"],
                                       extra["n_buckets"])
                agg_s = f"{agg:.2f}/s" if agg else "n/a"
                print(f"fleet_bench,{backend},S={S},mesh={ndev}dev,"
                      f"aggregate={agg_s},"
                      f"compiles={extra['n_compiles_total']},"
                      f"occupancy={extra['occupancy_per_device']}",
                      flush=True)
            if args.check_compiles:
                vals = set(mesh_compiles.values())
                assert len(vals) == 1, \
                    f"S={S}: fleet compile counts vary with device " \
                    f"count: {mesh_compiles}"
                compiles, n_buckets = vals.pop()
                assert compiles <= 3 * n_buckets, \
                    f"S={S} mesh: {compiles} traces for {n_buckets} " \
                    f"buckets (must be <= 3/bucket); retrace causes: " \
                    f"{extra['retrace_causes']}"
                print(f"fleet_bench,{backend},S={S},mesh compile check "
                      f"OK {mesh_compiles}", flush=True)

    if args.check_compiles:
        for S, (compiles, n_buckets) in fleet_compiles.items():
            assert compiles <= 3 * n_buckets, \
                f"S={S}: {compiles} fleet traces for {n_buckets} buckets " \
                f"(must be <= 3/bucket); retrace causes: {fleet_retraces}"
        if len(fleet_compiles) > 1:
            vals = set(fleet_compiles.values())
            assert len(vals) == 1, \
                f"fleet compile counts vary with S: {fleet_compiles}"
        print(f"fleet_bench,{backend},compile check OK {fleet_compiles}",
              flush=True)
        if SPEEDUP_TARGET_S in sizes and backend == "xla":
            sp = [r["speedup_aggregate"] for r in rows
                  if r.get("summary") and r["S"] == SPEEDUP_TARGET_S][0]
            assert sp is not None and sp >= SPEEDUP_TARGET, \
                f"S={SPEEDUP_TARGET_S} speedup {sp} < {SPEEDUP_TARGET}x"
            print(f"fleet_bench,{backend},speedup check OK ({sp:.2f}x)",
                  flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: S=4, small GP buckets, xla only")
    ap.add_argument("--rounds", type=int, default=None,
                    help="ask/tell rounds per study (incl. startup)")
    ap.add_argument("--fleet-sizes", type=int, nargs="+", default=None)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--backends", nargs="+", default=None,
                    choices=("xla", "pallas", "pallas_interpret"))
    ap.add_argument("--check-compiles", action="store_true")
    ap.add_argument("--mesh", type=int, default=None,
                    help="also run the fleet sharded over 1..N devices "
                    "(needs --xla_force_host_platform_device_count>=N "
                    "or N real devices)")
    ap.add_argument("--chaos", action="store_true",
                    help="add a journaled kill-and-recover row (fault "
                    "injection): recovery time + goodput under faults")
    ap.add_argument("--debug-nans", action="store_true",
                    help="wrap the three fleet block programs in a "
                    "finite-guard: every float leaf entering/leaving "
                    "them is checked; raises NonFiniteError naming the "
                    "program and leaf (one host sync per call)")
    ap.add_argument("--trace", action="store_true",
                    help="enable the obs span tracer (off by default); "
                    "adds a per-phase breakdown to the summary and "
                    "writes the Chrome-trace JSON to --trace-out")
    ap.add_argument("--trace-out", default="BENCH_fleet_trace.json")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)

    if args.trace:
        obs_trace.enable()

    if args.mesh is not None and args.mesh > len(jax.devices()):
        raise SystemExit(
            f"--mesh {args.mesh} needs {args.mesh} visible devices, have "
            f"{len(jax.devices())} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={args.mesh})")

    if args.tiny:
        args.rounds = args.rounds or 14
        args.D, args.B, args.pad = 3, 4, 8
        args.refit_interval, args.n_startup = 4, 4
        args.slots = args.slots or 4
        args.fleet_sizes = args.fleet_sizes or [4]
        args.backends = args.backends or ["xla"]
    else:
        args.rounds = args.rounds or 34
        args.D, args.B, args.pad = 6, 10, 32
        args.refit_interval, args.n_startup = 8, 10
        args.slots = args.slots or 16
        args.fleet_sizes = args.fleet_sizes or [1, 4, 16, 64]
        args.backends = args.backends or ["xla", "pallas_interpret"]

    out = []
    for backend in args.backends:
        sizes = args.fleet_sizes
        if backend != "xla":
            # interpret-mode emulation is slow; cover the scaling story
            # with the endpoints
            sizes = [S for S in sizes if S <= SPEEDUP_TARGET_S]
        out.extend(bench_backend(backend, sizes, args))

    if args.chaos:
        out.append(run_chaos(args.fleet_sizes[0], "xla", args))

    # headline scalars, one per configuration — dashboards and PR diffs
    # read these without walking the row arrays
    summary = {}
    if args.trace:
        events = obs_trace.get().events()
        summary["phase_breakdown"] = obs_export.phase_breakdown(events)
        obs_export.write_chrome_trace(
            args.trace_out, events, process_name="fleet_throughput",
            meta={"bench": "fleet_throughput"})
        print(f"wrote {args.trace_out} ({len(events)} trace events)")
    for r in out:
        if r.get("summary"):
            summary[f"{r['backend']}_S{r['S']}_speedup_aggregate"] = \
                r["speedup_aggregate"]
            if r["speedup_steady"] is not None:
                summary[f"{r['backend']}_S{r['S']}_speedup_steady"] = \
                    r["speedup_steady"]
        elif r.get("mode") == "fleet_mesh":
            summary[f"{r['backend']}_S{r['S']}_mesh{r['mesh_devices']}"
                    f"_aggregate_sps"] = r["suggests_per_sec_aggregate"]
        elif r.get("mode") == "fleet":
            summary[f"{r['backend']}_S{r['S']}_retrace_causes"] = \
                r["retrace_causes"]
            if "nan_guard" in r:
                summary[f"{r['backend']}_S{r['S']}_nan_guard_checks"] = \
                    r["nan_guard"]["n_guard_checks"]
        elif r.get("mode") == "fleet_chaos":
            summary[f"{r['backend']}_S{r['S']}_chaos_replay_ms_per"
                    f"_100_trials"] = r["replay_ms_per_100_trials"]
            summary[f"{r['backend']}_S{r['S']}_chaos_goodput_sps"] = \
                r["goodput_sps"]
            summary[f"{r['backend']}_S{r['S']}_chaos_goodput_post"
                    f"_recovery_sps"] = r["goodput_post_recovery_sps"]
            summary[f"{r['backend']}_S{r['S']}_chaos_inflight"
                    f"_at_crash"] = r["inflight_at_crash"]
            summary[f"{r['backend']}_S{r['S']}_chaos_deadline_miss"] = \
                r["deadline_miss"]
            summary[f"{r['backend']}_S{r['S']}_chaos_shed"] = r["shed"]
            summary[f"{r['backend']}_S{r['S']}_chaos_retrace_causes"] = \
                r["retrace_causes"]

    record = {
        "bench": "fleet_throughput",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "device": jax.devices()[0].device_kind,
        "jax_backend": jax.default_backend(),
        "python": platform.python_version(),
        "mode": "tiny" if args.tiny else "default",
        "mesh": args.mesh,
        "summary": summary,
        "rows": out,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {args.out} ({len(out)} rows)")
    return out


if __name__ == "__main__":
    main()
