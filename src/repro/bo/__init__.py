from repro.bo.space import BoxSpace
from repro.bo.journal import InjectedCrash, StudyJournal
from repro.bo.sampler import FleetSampler, GPSampler, RecoveryReport
from repro.bo.objectives import make_objective, OBJECTIVES
