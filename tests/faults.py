"""Deterministic fault injection for the fleet's robustness layer.

The :class:`FaultInjector` duck-types the three chaos hooks the
production code exposes (``StudyJournal``, ``FleetEngine``,
``AskEngine`` all take a ``fault_injector=``):

* ``should_kill(seq)`` — the journal calls this before each append; when
  it fires, the journal writes a deliberately *partial* record (exactly
  the on-disk state a real ``kill -9`` mid-append leaves), fsyncs it,
  and raises :class:`repro.bo.journal.InjectedCrash`.
* ``incr_ok(ok, sids)`` — veto the incremental rank-one update's health
  flag, forcing the exactness fallback (full refit) deterministically.
* ``full_ok(ok, sids)`` — mark a full MAP refit unhealthy, forcing the
  quarantine → retry → park path deterministically.

All hooks are host-side: an injector changes scheduling decisions, never
traced code, so the compile-economy invariants must hold under chaos.

The injector is deliberately one-shot / budgeted: a crash fires once
(real processes die once), and the ok vetoes decrement per-study budgets
so a test can script "study 1's next two full refits are unhealthy"
exactly.  ``sids`` may contain ``None`` entries — idle fleet slots, or
the solo ``AskEngine`` (which has no study id); budget vetoes keyed on
``None`` target those.
"""
from typing import Dict, Hashable, Optional

import numpy as np


class FaultInjector:
    """Scriptable chaos: journal kills + refit-health vetoes.

    Parameters
    ----------
    kill_at_seq:
        Journal sequence number at which to simulate a process kill
        (one-shot: fires on the first append with ``seq >= kill_at_seq``
        and then disarms, so a recovered run using the same injector
        keeps running).
    incr_fail:
        ``{sid: budget}`` — veto up to ``budget`` healthy incremental
        ``ok`` flags for that study (``None`` targets the solo
        AskEngine / anonymous slots).
    full_fail:
        ``{sid: budget}`` — mark up to ``budget`` full refits for that
        study unhealthy.
    """

    def __init__(self, *, kill_at_seq: Optional[int] = None,
                 incr_fail: Optional[Dict[Hashable, int]] = None,
                 full_fail: Optional[Dict[Hashable, int]] = None):
        self.kill_at_seq = kill_at_seq
        self.incr_fail = dict(incr_fail or {})
        self.full_fail = dict(full_fail or {})
        self.n_kills = 0
        self.n_incr_vetoed = 0
        self.n_full_vetoed = 0

    # ------------------------------------------------------ journal hook
    def should_kill(self, seq: int) -> bool:
        if self.kill_at_seq is not None and seq >= self.kill_at_seq:
            self.kill_at_seq = None          # one-shot: processes die once
            self.n_kills += 1
            return True
        return False

    # ------------------------------------------------- refit-health hooks
    def _veto(self, budgets: Dict[Hashable, int], ok: np.ndarray,
              sids) -> np.ndarray:
        ok = np.array(ok)
        for i, sid in enumerate(sids):
            if ok[i] and budgets.get(sid, 0) > 0:
                ok[i] = False
                budgets[sid] -= 1
        return ok

    def incr_ok(self, ok, sids) -> np.ndarray:
        before = int(np.sum(np.asarray(ok)))
        out = self._veto(self.incr_fail, ok, sids)
        self.n_incr_vetoed += before - int(np.sum(out))
        return out

    def full_ok(self, ok, sids) -> np.ndarray:
        before = int(np.sum(np.asarray(ok)))
        out = self._veto(self.full_fail, ok, sids)
        self.n_full_vetoed += before - int(np.sum(out))
        return out
