"""GPSampler-style Bayesian-optimization controller (ask/tell).

This is the Optuna-integration analogue the paper ships: each `ask` fits a
Matérn-5/2 GP on the observations, builds LogEI, and runs multi-start
L-BFGS-B with a pluggable MSO strategy (`seq` / `cbe` / `dbe` / `dbe_vec`).

Two suggest pipelines sit behind `ask()`:

* the **host pipeline** (scipy strategies, and `dbe_vec` with
  ``fused=False``): from-scratch `fit_gp` + host restart sampling +
  `maximize_acqf` — one device round trip per stage;
* the **fused pipeline** (default for `dbe_vec`): the whole
  standardize → (incremental or full) refit → restart sampling → lockstep
  MSO → argmax chain runs as ONE compiled device program per GP size
  bucket (`engine/ask.py`), with rank-one GP updates between full refits.

Fault tolerance at the controller level: every suggestion is journaled
before being handed out; `tell` completes it; a crashed/preempted trial is
simply re-suggested on resume (`GPSampler.load`).  The controller is the BO
"control plane" driving the distributed trainer in `examples/hpo_train.py`.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bo.space import BoxSpace
from repro.core.acquisition import logei_acq
from repro.core.lbfgsb import LbfgsbOptions
from repro.core.mso import MsoOptions, MsoResult, maximize_acqf
from repro.engine import (AskConfig, AskEngine, EvalEngine, fused_logei_acq,
                          resolve_backend)
from repro.gp.fit import (fit_gp, pad_bucket_for, standardize,
                          standardize_masked)
from repro.gp.gpr import with_kinv


def _standardize_bucketed(y: np.ndarray, pad: int) -> jax.Array:
    """Standardize ``y`` with the moments computed over a pad-bucketed
    masked reduction — bit-identical to the fused ask program's
    ``standardize_masked``, sliced back to the live entries."""
    n = y.shape[0]
    b = pad_bucket_for(n, pad)
    y_pad = jnp.zeros((b,), jnp.asarray(y).dtype).at[:n].set(jnp.asarray(y))
    y_std, _, _ = standardize_masked(y_pad, jnp.arange(b) < n)
    return y_std[:n]


@dataclass
class Trial:
    trial_id: int
    x: np.ndarray
    y: Optional[float] = None
    state: str = "pending"           # pending | complete | failed
    ask_time: float = 0.0
    tell_time: float = 0.0
    error: Optional[str] = None      # failure reason (failed trials)


@dataclass
class SamplerStats:
    n_gp_fits: int = 0
    fit_time: float = 0.0
    acqf_time: float = 0.0
    acqf_iters: List[float] = field(default_factory=list)
    acqf_rounds: List[int] = field(default_factory=list)
    engine: Optional[dict] = None       # last EvalEngine.stats_snapshot()


class GPSampler:
    """Ask/tell BO over a box space; strategy selects the MSO scheme."""

    def __init__(
        self,
        space: BoxSpace,
        *,
        strategy: str = "dbe",
        n_startup_trials: int = 10,
        n_restarts: int = 10,
        mso_options: Optional[MsoOptions] = None,
        seed: int = 0,
        pad_multiple: int = 32,
        gp_fit_restarts: int = 2,
        posterior_backend: str = "auto",
        fused: Optional[bool] = None,
        refit_interval: int = 8,
        warm_start: bool = True,
    ):
        self.space = space
        self.strategy = strategy
        self.n_startup = n_startup_trials
        self.B = n_restarts
        # fresh per instance: a shared default dataclass would leak option
        # mutations across samplers
        self.mso_options = (mso_options if mso_options is not None
                            else MsoOptions())
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.pad_multiple = pad_multiple
        self.gp_fit_restarts = gp_fit_restarts
        self.posterior_backend = resolve_backend(posterior_backend)
        # fused one-program ask(): default for the device-resident
        # strategy; the scipy strategies drive scipy from the host and
        # cannot run inside one program
        self.fused = (strategy == "dbe_vec") if fused is None else bool(fused)
        if self.fused and strategy != "dbe_vec":
            raise ValueError("fused ask() requires strategy='dbe_vec'; "
                             f"got {strategy!r}")
        self.refit_interval = refit_interval
        self.warm_start = warm_start
        # ONE evaluation engine for the whole BO run: every trial's MSO
        # (any strategy) reuses its shape-bucketed jit caches, so compile
        # counts stay O(log B · #GP-size-buckets), not O(trials)
        self._acq_fn = (logei_acq if self.posterior_backend == "xla"
                        else fused_logei_acq(self.posterior_backend))
        self.engine = EvalEngine(self._acq_fn)
        self._ask: Optional[AskEngine] = None       # fused pipeline state
        self._fleet = None                          # attached FleetEngine
        self._fleet_sid = None                      # our study id in it
        self._observed_ids: set = set()             # trials in the ask GP
        self._base_key = jax.random.PRNGKey(seed)   # restart-point stream
        self.trials: List[Trial] = []
        self.stats = SamplerStats()
        self.last_mso: Optional[MsoResult] = None
        self.last_ask_info = None        # SuggestInfo of last fused ask

    # ----------------------------------------------------------------- api
    def ask(self) -> Trial:
        n_done = sum(t.state == "complete" for t in self.trials)
        if n_done < self.n_startup:
            x = self.space.sample(self.rng, 1)[0]
        else:
            x = self._suggest()
        t = Trial(trial_id=len(self.trials), x=x, ask_time=time.time())
        self.trials.append(t)
        return t

    def tell(self, trial_id: int, y: float, *, failed: bool = False,
             error: Optional[str] = None):
        t = self.trials[trial_id]
        t.y = None if failed else float(y)
        t.state = "failed" if failed else "complete"
        t.error = error if failed else None
        t.tell_time = time.time()

    def best(self) -> Trial:
        done = [t for t in self.trials if t.state == "complete"]
        if not done:
            failed = [t for t in self.trials if t.state == "failed"]
            msg = (f"no completed trials to report a best from "
                   f"({len(self.trials)} trials: {len(failed)} failed, "
                   f"{len(self.trials) - len(failed)} pending)")
            errors = [t.error for t in failed if t.error]
            if errors:
                msg += f"; last failure: {errors[-1]}"
            raise RuntimeError(msg)
        return min(done, key=lambda t: t.y)

    def optimize(self, objective, n_trials: int):
        for _ in range(n_trials):
            t = self.ask()
            try:
                self.tell(t.trial_id, objective(t.x))
            except Exception as e:          # noqa: BLE001 — trial isolation
                # keep the run alive but preserve the reason: best() and
                # the journal surface it instead of a silent failed state
                self.tell(t.trial_id, 0.0, failed=True,
                          error=f"{type(e).__name__}: {e}")
        return self.best()

    # -------------------------------------------------------- inner engine
    def _observations(self):
        done = [t for t in self.trials if t.state == "complete"]
        X = np.stack([t.x for t in done])
        y = np.array([t.y for t in done])
        return X, y

    def _suggest(self) -> np.ndarray:
        if self.fused:
            return self._suggest_fused()
        X, y = self._observations()
        U = self.space.to_unit(X)
        # minimize y == maximize -y (standardized)
        t0 = time.perf_counter()
        if self.strategy == "dbe_vec":
            # run the moments through the same padded masked reduction the
            # fused program uses: reduction shape changes the last-ulp
            # rounding, and the MAP fit amplifies a 1-ulp y_std difference
            # into visibly different hyperparameters
            y_std = _standardize_bucketed(-y, self.pad_multiple)
        else:
            y_std, _, _ = standardize(jnp.asarray(-y))
        gp = fit_gp(jnp.asarray(U), y_std, n_restarts=self.gp_fit_restarts,
                    seed=self.seed + len(self.trials),
                    pad_bucket=self.pad_multiple)
        if self.posterior_backend != "xla":
            gp = with_kinv(gp)      # fused quadratic-form posterior input
        self.stats.n_gp_fits += 1
        self.stats.fit_time += time.perf_counter() - t0

        best_val = jnp.max(y_std)

        # restart points: incumbent + (B-1) uniform (GPSampler-style).
        # dbe_vec draws them from the jax PRNG stream so the unfused path
        # stays trajectory-identical to the fused one-program ask()
        inc = U[int(np.argmin(y))]
        if self.strategy == "dbe_vec":
            rand = np.asarray(jax.random.uniform(
                self._restart_key(), (self.B - 1, self.space.dim),
                jnp.asarray(U).dtype))
        else:
            rand = self.rng.uniform(0.0, 1.0, (self.B - 1, self.space.dim))
        x0 = np.concatenate([inc[None], rand], 0)

        t0 = time.perf_counter()
        res = maximize_acqf(self._acq_fn, x0, 0.0, 1.0,
                            acq_state=(gp, best_val),
                            strategy=self.strategy,
                            options=self.mso_options,
                            engine=self.engine)
        self.stats.acqf_time += time.perf_counter() - t0
        self.stats.acqf_iters.append(float(np.median(res.n_iters)))
        self.stats.acqf_rounds.append(res.n_rounds)
        self.stats.engine = res.engine_stats
        self.last_mso = res
        return self.space.from_unit(np.clip(res.best_x, 0.0, 1.0))

    # ------------------------------------------------------- fused path
    def _restart_key(self):
        """Per-trial PRNG key for restart sampling (fused and unfused
        dbe_vec share it — same key ⇒ same restart points)."""
        return jax.random.fold_in(self._base_key, len(self.trials))

    def _suggest_fused(self) -> np.ndarray:
        if self._fleet is not None:
            return self._suggest_fleet()
        done = [t for t in self.trials if t.state == "complete"]
        if self._ask is None:
            o = self.mso_options
            self._ask = AskEngine(self.engine, AskConfig(
                dim=self.space.dim, n_restarts=self.B,
                backend=self.posterior_backend,
                pad_bucket=self.pad_multiple,
                refit_interval=self.refit_interval,
                warm_start=self.warm_start,
                gp_fit_restarts=self.gp_fit_restarts,
                mso=LbfgsbOptions(m=o.m, maxiter=o.maxiter, pgtol=o.pgtol,
                                  ftol=o.ftol, maxls=o.maxls)))
        ask = self._ask
        # lazy observation sync covers tell() and journal resume alike;
        # keyed by trial id, not list position — out-of-order tells must
        # not duplicate/drop observations (the host path rebuilds X, y
        # from scratch each trial and is naturally immune)
        for t in done:
            if t.trial_id not in self._observed_ids:
                ask.observe(self.space.to_unit(t.x), t.y)
                self._observed_ids.add(t.trial_id)

        t0 = time.perf_counter()
        best_x, info = ask.suggest(self._restart_key(),
                                   fit_seed=self.seed + len(self.trials))
        wall = time.perf_counter() - t0
        return self._record_fused_suggest(
            best_x, info, wall,
            {**self.engine.stats_snapshot(), **ask.stats_snapshot()})

    def _record_fused_suggest(self, best_x, info, wall, snapshot):
        """Shared stats tail of the fused/fleet suggest paths.  Per-
        restart state stays on device in both — only the suggestion (and
        scalar diagnostics) ever reach the host."""
        if info.kind != "incremental":
            self.stats.n_gp_fits += 1
        self.stats.acqf_time += wall
        self.stats.acqf_iters.append(
            float(np.median(np.asarray(info.n_iters))))
        self.stats.acqf_rounds.append(int(info.rounds))
        self.stats.engine = snapshot
        self.last_mso = None
        self.last_ask_info = info
        return self.space.from_unit(np.clip(best_x, 0.0, 1.0))

    # ------------------------------------------------------- fleet path
    def attach_fleet(self, fleet, study_id=None) -> "GPSampler":
        """Route this sampler's fused ask() through a shared
        :class:`~repro.engine.fleet.FleetEngine` (one compiled program
        serves every attached study's suggest).

        Must be called before the first trial; the fleet's static config
        must match this sampler's (dim, restarts, bucketing, backend) or
        the stacked programs would not reproduce the solo pipeline.
        Returns ``self`` for chaining.
        """
        if not self.fused:
            raise ValueError("attach_fleet() requires the fused dbe_vec "
                             "pipeline (strategy='dbe_vec', fused=True)")
        if self.trials or self._ask is not None:
            raise ValueError("attach_fleet() must be called before the "
                             "first trial")
        cfg = fleet.cfg
        o = self.mso_options
        mine = dict(dim=self.space.dim, n_restarts=self.B,
                    pad_bucket=self.pad_multiple,
                    backend=self.posterior_backend,
                    refit_interval=self.refit_interval,
                    warm_start=self.warm_start,
                    gp_fit_restarts=self.gp_fit_restarts,
                    mso=(o.m, o.maxiter, o.pgtol, o.ftol, o.maxls))
        theirs = {k: getattr(cfg, k) for k in mine if k != "mso"}
        theirs["mso"] = (cfg.mso.m, cfg.mso.maxiter, cfg.mso.pgtol,
                         cfg.mso.ftol, cfg.mso.maxls)
        if mine != theirs:
            raise ValueError(f"fleet config mismatch: sampler has {mine}, "
                             f"fleet has {theirs}")
        sid = study_id if study_id is not None else f"study-{id(self):x}"
        fleet.add_study(sid)
        self._fleet, self._fleet_sid = fleet, sid
        return self

    def _sync_fleet_observations(self) -> None:
        for t in self.trials:
            if t.state == "complete" and t.trial_id not in self._observed_ids:
                self._fleet.observe(self._fleet_sid,
                                    self.space.to_unit(t.x), t.y)
                self._observed_ids.add(t.trial_id)

    def prefetch_suggest(self) -> bool:
        """Enqueue this sampler's next suggest into the attached fleet
        WITHOUT running it — the caller batches many studies' requests
        into one ``fleet.step()`` and then calls ``ask()`` to collect.
        Returns False while the sampler is still in random startup (no
        request enqueued)."""
        if self._fleet is None:
            raise ValueError("no fleet attached")
        n_done = sum(t.state == "complete" for t in self.trials)
        if n_done < self.n_startup:
            return False
        self._sync_fleet_observations()
        self._fleet.request_suggest(self._fleet_sid, self._restart_key(),
                                    self.seed + len(self.trials))
        return True

    def _suggest_fleet(self) -> np.ndarray:
        self._sync_fleet_observations()
        t0 = time.perf_counter()
        res = self._fleet.pop_result(self._fleet_sid)
        if res is None:       # solo path: request + step + collect now
            res = self._fleet.suggest(self._fleet_sid, self._restart_key(),
                                      self.seed + len(self.trials))
        best_x, info = res
        wall = time.perf_counter() - t0
        return self._record_fused_suggest(
            best_x, info, wall,
            {**self._fleet.engine.stats_snapshot(),
             **self._fleet.stats_snapshot()})

    # ------------------------------------------------- journal (restart)
    def save(self, path: str):
        rec = {
            "seed": self.seed,
            "strategy": self.strategy,
            "lower": self.space.lower.tolist(),
            "upper": self.space.upper.tolist(),
            "trials": [
                dict(trial_id=t.trial_id, x=t.x.tolist(), y=t.y,
                     state=t.state, error=t.error) for t in self.trials
            ],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, path)        # atomic

    @classmethod
    def load(cls, path: str, **kwargs) -> "GPSampler":
        with open(path) as f:
            rec = json.load(f)
        space = BoxSpace(np.array(rec["lower"]), np.array(rec["upper"]))
        s = cls(space, strategy=rec["strategy"], seed=rec["seed"], **kwargs)
        for tr in rec["trials"]:
            t = Trial(trial_id=tr["trial_id"], x=np.array(tr["x"]),
                      y=tr["y"], state=tr["state"],
                      error=tr.get("error"))
            if t.state == "pending":
                # a trial that never came back (crash/preemption):
                # mark failed; its parameters will be re-explored naturally.
                t.state = "failed"
                t.error = "trial never completed (crash/preemption)"
            s.trials.append(t)
        return s


class FleetSampler:
    """Drive S concurrent BO studies through ONE fleet ask plane.

    One :class:`~repro.engine.fleet.FleetEngine` (and one
    :class:`~repro.engine.EvalEngine`) serves every study: each round,
    all studies' suggest requests are enqueued (`prefetch_suggest`),
    ONE ``fleet.step()`` runs the stacked device programs, and each
    study's :class:`GPSampler` collects its suggestion from the shared
    batch.  Per-study trajectories are bit-for-bit what the same sampler
    would produce solo (same seeds ⇒ same PRNG streams; the fleet's
    masking guarantees slot/batch independence).

    ``spaces`` may be one :class:`BoxSpace` (replicated S times via
    ``n_studies``) or an explicit list; every study shares the static
    fleet config (dim, restarts, bucketing, backend).

    ``mesh`` (optional): a 1-D study mesh
    (:func:`repro.launch.mesh.make_fleet_mesh`).  Slot blocks then hold
    ``slots`` studies PER DEVICE (``slots × ndev`` total), sharded over
    the mesh's study axis, and the fleet programs run under ``shard_map``
    — per-study trajectories stay bit-for-bit identical to any other
    placement, including no mesh at all.
    """

    def __init__(
        self,
        spaces,
        *,
        n_studies: Optional[int] = None,
        seed: int = 0,
        slots: int = 8,
        strategy: str = "dbe_vec",
        n_startup_trials: int = 10,
        n_restarts: int = 10,
        mso_options: Optional[MsoOptions] = None,
        pad_multiple: int = 32,
        gp_fit_restarts: int = 2,
        posterior_backend: str = "auto",
        refit_interval: int = 8,
        warm_start: bool = True,
        mesh=None,
    ):
        from repro.engine import FleetConfig, FleetEngine
        from repro.core.lbfgsb import LbfgsbOptions

        if strategy != "dbe_vec":
            raise ValueError("FleetSampler requires strategy='dbe_vec'")
        if isinstance(spaces, BoxSpace):
            spaces = [spaces] * int(n_studies if n_studies else 1)
        dims = {sp.dim for sp in spaces}
        if len(dims) != 1:
            raise ValueError(f"all studies must share one dim, got {dims}")
        backend = resolve_backend(posterior_backend)
        o = mso_options if mso_options is not None else MsoOptions()
        acq = logei_acq if backend == "xla" else fused_logei_acq(backend)
        self.engine = EvalEngine(acq)
        self.fleet = FleetEngine(self.engine, FleetConfig(
            dim=dims.pop(), n_restarts=n_restarts, slots=slots,
            backend=backend, pad_bucket=pad_multiple,
            refit_interval=refit_interval, warm_start=warm_start,
            gp_fit_restarts=gp_fit_restarts,
            mso=LbfgsbOptions(m=o.m, maxiter=o.maxiter, pgtol=o.pgtol,
                              ftol=o.ftol, maxls=o.maxls)), mesh=mesh)
        self.samplers = [
            GPSampler(sp, strategy="dbe_vec", fused=True, seed=seed + i,
                      n_startup_trials=n_startup_trials,
                      n_restarts=n_restarts, mso_options=replace(o),
                      pad_multiple=pad_multiple,
                      gp_fit_restarts=gp_fit_restarts,
                      posterior_backend=backend,
                      refit_interval=refit_interval,
                      warm_start=warm_start,
                      ).attach_fleet(self.fleet, study_id=i)
            for i, sp in enumerate(spaces)]

    def __len__(self) -> int:
        return len(self.samplers)

    def ask_all(self) -> List[Trial]:
        """One fleet trial boundary: enqueue every study's suggest, run
        ONE batched step, collect per-study trials (startup studies
        sample randomly and skip the batch)."""
        for s in self.samplers:
            s.prefetch_suggest()
        self.fleet.step()
        return [s.ask() for s in self.samplers]

    def tell(self, study: int, trial_id: int, y: float, **kw) -> None:
        self.samplers[study].tell(trial_id, y, **kw)

    def optimize(self, objectives, n_rounds: int) -> List[Trial]:
        """Run ``n_rounds`` synchronized ask/tell rounds; ``objectives``
        is one callable (shared) or one per study.  Returns per-study
        best trials."""
        if callable(objectives):
            objectives = [objectives] * len(self.samplers)
        for _ in range(n_rounds):
            trials = self.ask_all()
            for s, (smp, t) in enumerate(zip(self.samplers, trials)):
                try:
                    smp.tell(t.trial_id, objectives[s](t.x))
                except Exception as e:   # noqa: BLE001 — trial isolation
                    smp.tell(t.trial_id, 0.0, failed=True,
                             error=f"{type(e).__name__}: {e}")
        return [s.best() for s in self.samplers]

    def stats_snapshot(self) -> dict:
        return {**self.engine.stats_snapshot(),
                **self.fleet.stats_snapshot()}
