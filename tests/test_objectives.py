"""BBOB objective sanity + search-space tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bo.objectives import OBJECTIVES, make_objective
from repro.bo.space import BoxSpace


@pytest.mark.parametrize("name", [o for o in OBJECTIVES
                                  if o != "rosenbrock"])
@pytest.mark.parametrize("dim", [2, 5, 10])
def test_optimum_value(name, dim):
    f = make_objective(name, dim, seed=3)
    v_opt = f(f.x_opt)
    assert v_opt <= 1e-9, (name, v_opt)
    rng = np.random.default_rng(0)
    for _ in range(16):
        x = rng.uniform(-5, 5, dim)
        assert f(x) >= v_opt - 1e-12


def test_rosenbrock_optimum():
    f = make_objective("rosenbrock", 5)
    assert f(np.ones(5)) == 0.0


def test_instances_differ_by_seed():
    f1 = make_objective("rastrigin", 4, seed=1)
    f2 = make_objective("rastrigin", 4, seed=2)
    assert not np.allclose(f1.x_opt, f2.x_opt)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_space_roundtrip(seed):
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-10, 0, 4)
    hi = lo + rng.uniform(0.5, 10, 4)
    sp = BoxSpace(lo, hi)
    x = sp.sample(rng, 8)
    u = sp.to_unit(x)
    assert np.all(u >= -1e-12) and np.all(u <= 1 + 1e-12)
    np.testing.assert_allclose(sp.from_unit(u), x, atol=1e-10)


def test_space_validation():
    with pytest.raises(ValueError):
        BoxSpace(np.array([0.0, 1.0]), np.array([1.0, 1.0]))
