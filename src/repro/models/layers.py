"""Transformer building blocks: norms, RoPE, GQA attention, MLP.

Pure functions over Boxed-param pytrees.  Attention has three execution
paths sharing one interface:

* ``chunked`` — pure-XLA flash-style scan over query blocks (the dry-run /
  training path; keeps the (S, S) score matrix out of live memory),
* ``pallas``  — `repro.kernels.flash` (TPU serving/prefill path),
* ``decode``  — single-query attention over a KV cache.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import Boxed, box, constrain
from repro.models.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, in_axis_size):
    scale = in_axis_size ** -0.5
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def make_dense(key, d_in, d_out, dtype, axes) -> Boxed:
    return box(_dense_init(key, (d_in, d_out), dtype, d_in), *axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype) -> dict:
    p = {"scale": box(jnp.ones((cfg.d_model,), dtype), "embed")}
    if cfg.norm == "layernorm":
        p["bias"] = box(jnp.zeros((cfg.d_model,), dtype), "embed")
    return p


def apply_norm(p: dict, x: Array, kind: str, eps: float = 1e-6) -> Array:
    """Stats in f32, products in x.dtype.

    Deliberately avoids materializing an f32 copy of x: the reductions fuse
    convert(x) away, whereas an f32 x tensor with multiple consumers gets
    hoisted OUT of the layer loop by XLA into a (layers, B, S, D) f32 stack
    — 2× the remat carry budget (see EXPERIMENTS.md §Perf #7).
    """
    cdt = jnp.promote_types(x.dtype, jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(x.astype(cdt)), -1, keepdims=True)
        inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
        out = x * inv * p["scale"].value
    else:
        mu = jnp.mean(x.astype(cdt), -1, keepdims=True)
        var = jnp.var(x.astype(cdt), -1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        out = ((x - mu.astype(x.dtype)) * inv.astype(x.dtype)
               * p["scale"].value)
    if "bias" in p:
        out = out + p["bias"].value
    return out


# ---------------------------------------------------------------------------
# rotary embeddings (full or partial / "2d" fraction)
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float, fraction: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * \
        freqs[None, None, None, :]            # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half].astype(jnp.float32), \
        xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    p = {
        "wq": box(_dense_init(kq, (d, nh, hd), dtype, d),
                  "embed", "heads", None),
        "wk": box(_dense_init(kk, (d, nkv, hd), dtype, d),
                  "embed", "kv_heads", "head"),
        "wv": box(_dense_init(kv, (d, nkv, hd), dtype, d),
                  "embed", "kv_heads", "head"),
        "wo": box(_dense_init(ko, (nh, hd, d), dtype, nh * hd),
                  "heads", None, "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = box(jnp.ones((hd,), dtype), None)
        p["k_norm"] = box(jnp.ones((hd,), dtype), None)
    return p


def _qk_normalize(x: Array, scale: Array) -> Array:
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf * scale).astype(x.dtype)


def _grouped_scores(q: Array, k: Array) -> Array:
    """q: (B, Sq, KH, G, hd), k: (B, Sk, KH, hd) → (B, KH, G, Sq, Sk).

    Grouped form never materializes repeated KV heads — on decode the KV
    cache read is the roofline term, so bytes stay at kv_heads, not heads.
    """
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _attend_block(q, k, v, mask):
    """q: (B,Sq,KH,G,hd); k/v: (B,Sk,KH,hd); mask: (B,1,1,Sq,Sk) bool."""
    hd = q.shape[-1]
    s = _grouped_scores(q, k) * (hd ** -0.5)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out


def attention_xla(q: Array, k: Array, v: Array, *, causal: bool,
                  window: int, q_pos: Array, kv_pos: Array,
                  chunk: int = 0) -> Array:
    """Chunked XLA attention.  q: (B, Sq, NH, hd), k/v: (B, Sk, KH, hd).

    All masking is position-based: ``q_pos`` (B, Sq) and ``kv_pos`` (B, Sk)
    hold absolute token positions; kv slots with position −1 are invalid
    (ring-buffer / unfilled cache).  ``chunk``: query-block size; 0 or
    >= Sq disables chunking.
    """
    B, Sq, NH, hd = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = NH // KH
    qg = q.reshape(B, Sq, KH, G, hd)

    ik = kv_pos[:, None, None, None, :]                 # (B,1,1,1,Sk)
    valid = ik >= 0

    def mask_for(iq_abs):
        # iq_abs: (B, c) absolute positions of this query block
        iq = iq_abs[:, None, None, :, None]
        m = valid
        if causal:
            m = m & (ik <= iq)
        if window:
            m = m & (ik > iq - window)
        return m

    if chunk <= 0 or chunk >= Sq or Sq % chunk != 0:
        out = _attend_block(qg, k, v, mask_for(q_pos))
        return out.reshape(B, Sq, NH, hd)
    n_chunks = Sq // chunk
    qg_c = qg.reshape(B, n_chunks, chunk, KH, G, hd).transpose(
        1, 0, 2, 3, 4, 5)                       # (C, B, chunk, KH, G, hd)
    qpos_c = q_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, args):
        qc, pc = args
        oc = _attend_block(qc, k, v, mask_for(pc))
        return carry, oc

    _, outs = lax.scan(body, None, (qg_c, qpos_c))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KH, G, hd)
    return out.reshape(B, Sq, NH, hd)


@dataclasses.dataclass(frozen=True)
class AttnTemps:
    """Static attention call profile (which path, masking, chunking)."""
    causal: bool = True
    window: int = 0
    chunk: int = 1024


def apply_attention(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                    *, window: int = 0,
                    cache: Optional[dict] = None,
                    cache_index: Optional[Array] = None,
                    causal: bool = True) -> Tuple[Array, Optional[dict]]:
    """Full attention sublayer.  x: (B, S, D).

    Without ``cache``: training/prefill self-attention.  With ``cache``:
    write this step's K/V at ``cache_index`` (ring-indexed when the cache is
    window-bounded) and attend over the valid slots; the cache carries a
    per-slot ``pos`` tensor so masking is exact across ring wraparound.
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].value)
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"].value)
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"].value)
    if cfg.qk_norm:
        q = _qk_normalize(q, p["q_norm"].value)
        k = _qk_normalize(k, p["k_norm"].value)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    if cache is None:
        q = constrain(q, "batch", None, "heads", None)
    else:
        # decode: the KV cache may be head-dim sharded (kv_heads often
        # indivisible by the model axis) — shard q the same way so the QK
        # contraction partial-sums over the sharded head dim (tiny score
        # psum) instead of all-gathering the cache (GiBs, f32).
        q = constrain(q, "batch", None, None, "head")
        k = constrain(k, "batch", None, "kv_heads", "head")
        v = constrain(v, "batch", None, "kv_heads", "head")

    new_cache = None
    if cache is None:
        out = attention_xla(q, k, v, causal=causal, window=window,
                            q_pos=positions,
                            kv_pos=positions, chunk=cfg.attn_chunk)
    else:
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        length = ck.shape[1]
        idx = jnp.asarray(cache_index)
        if idx.ndim == 0:
            # uniform write index (lockstep decode / prefill-fill)
            slot = (idx % length if window else idx).astype(jnp.int32)
            ck = lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), slot, axis=1)
            cv = lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), slot, axis=1)
            cpos = lax.dynamic_update_slice_in_dim(cpos, positions, slot,
                                                   axis=1)
        else:
            # per-row write index (continuous batching); S must be 1.
            # Convention: idx < 0 marks an inactive row — its write lands
            # in the reserved trash slot (length-1) with pos=-1, so idle
            # rows never corrupt live cache entries.
            assert S == 1, "vector cache_index requires single-token steps"
            slot = (idx % length if window else idx).astype(jnp.int32)
            slot = jnp.where(idx >= 0, slot, length - 1)
            bidx = jnp.arange(B)
            ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
            cpos = cpos.at[bidx, slot].set(positions[:, 0])
        out = attention_xla(q, ck, cv, causal=causal, window=window,
                            q_pos=positions, kv_pos=cpos, chunk=0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].value)
    return constrain(y, "batch", None, None), new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                    window: int = 0) -> dict:
    """Pre-allocated KV cache.  Local-attention layers bound it by window;
    ``pos`` holds each slot's absolute position (−1 = empty)."""
    length = min(max_len, window) if window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None
             ) -> dict:
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": box(_dense_init(k1, (d, ff), dtype, d), "embed", "ff"),
        "w_down": box(_dense_init(k2, (ff, d), dtype, ff), "ff", "embed"),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w_gate"] = box(_dense_init(k3, (d, ff), dtype, d), "embed", "ff")
    return p


def apply_mlp(p: dict, cfg: ModelConfig, x: Array) -> Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].value)
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].value)
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].value)
    return constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": box(jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                      dtype) * 0.02, "vocab", "embed")}
    if not cfg.tie_embeddings:
        p["head"] = box(
            _dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype,
                        cfg.d_model), "embed", "vocab")
    return p


def embed_tokens(p: dict, tokens: Array) -> Array:
    out = jnp.take(p["tok"].value, tokens, axis=0)
    return constrain(out, "batch", None, None)


def lm_logits(p: dict, cfg: ModelConfig, x: Array) -> Array:
    w = p["tok"].value.T if cfg.tie_embeddings else p["head"].value
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(logits, "batch", None, "vocab")
