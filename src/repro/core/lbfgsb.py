"""Batched bound-constrained L-BFGS-B in pure JAX.

This is the device-resident realization of the paper's D-BE scheme
("Decouple QN updates, Batch Evaluations"): every restart carries its own
limited-memory state stacked along a leading batch axis ``(B, m, D)``, all
restarts advance in lockstep inside one ``lax.while_loop``, and function
evaluations for all *active* restarts happen in a single batched call.
Because each restart's two-loop recursion reads only its own history slice,
the implied inverse-Hessian approximation is block-diagonal **by
construction** — the exact property the paper's coroutine buys on top of
scipy, with zero per-iteration host round trips.

Algorithm: projected quasi-Newton (Schmidt et al.) — gradient projection for
the bound active set + L-BFGS two-loop direction on the free variables +
projected-path backtracking Armijo line search.  Convergence criteria mirror
scipy's L-BFGS-B (``pgtol`` on the infinity norm of the projected gradient,
``ftol`` relative-decrease, ``maxiter``).

The same solver expresses all three of the paper's MSO schemes:

* D-BE  — call with the natural ``(B, D)`` restart layout (block states).
* C-BE  — call with ``B=1`` on the flattened ``(1, B*D)`` summed objective
          (one shared dense-over-BD state → off-diagonal artifacts).
* SEQ.  — call per-restart with ``B=1`` (reference trajectories).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

# Status codes (per restart).
RUNNING = 0
CONV_PGTOL = 1
CONV_FTOL = 2
CONV_MAXITER = 3
CONV_LS_FAIL = 4


class LbfgsbOptions(NamedTuple):
    m: int = 10
    maxiter: int = 200
    pgtol: float = 1e-5
    ftol: float = 1e-12          # relative f decrease; 0 disables
    maxls: int = 25
    armijo_c1: float = 1e-4
    ls_shrink: float = 0.5
    bound_eps: float = 1e-10     # active-set detection slack
    curv_eps: float = 1e-10      # curvature-pair acceptance threshold


class LbfgsbState(NamedTuple):
    """Stacked per-restart solver state. All leaves lead with B."""
    x: Array            # (B, D) current iterate (always inside [l, u])
    f: Array            # (B,)
    g: Array            # (B, D)
    s_hist: Array       # (B, m, D) displacement history (circular)
    y_hist: Array       # (B, m, D) gradient-difference history (circular)
    rho: Array          # (B, m)   1 / s.y per slot
    start: Array        # (B,) int32 circular-buffer head (oldest slot)
    length: Array       # (B,) int32 number of valid slots
    gamma: Array        # (B,)  H0 = gamma * I scaling
    k: Array            # (B,) int32 iteration count
    status: Array       # (B,) int32 RUNNING / CONV_*
    n_evals: Array      # (B,) int32 per-restart *active* objective evals
    rounds: Array       # () int32 number of batched evaluation rounds


class LbfgsbResult(NamedTuple):
    x: Array            # (B, D)
    f: Array            # (B,)
    g: Array            # (B, D)
    k: Array            # (B,) iterations taken
    status: Array       # (B,)
    n_evals: Array      # (B,)
    rounds: Array       # () total batched rounds (line-search rounds incl.)
    state: LbfgsbState  # final full state (history introspection)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _proj(x: Array, lower: Array, upper: Array) -> Array:
    return jnp.clip(x, lower, upper)


def projected_grad(x: Array, g: Array, lower: Array, upper: Array) -> Array:
    """scipy-style projected gradient: x - P(x - g)."""
    return x - _proj(x - g, lower, upper)


def _active_mask(x, g, lower, upper, eps):
    """Coordinates pinned at a bound with the gradient pushing outward."""
    at_lo = (x <= lower + eps) & (g > 0)
    at_hi = (x >= upper - eps) & (g < 0)
    return at_lo | at_hi


def _ordered_history(state: LbfgsbState, m: int):
    """Gather history slots in chronological order (j=0 oldest)."""
    B = state.x.shape[0]
    j = jnp.arange(m, dtype=jnp.int32)
    order = (state.start[:, None] + j[None, :]) % m               # (B, m)
    s_ord = jnp.take_along_axis(state.s_hist, order[:, :, None], axis=1)
    y_ord = jnp.take_along_axis(state.y_hist, order[:, :, None], axis=1)
    rho_ord = jnp.take_along_axis(state.rho, order, axis=1)
    valid = j[None, :] < state.length[:, None]                    # (B, m)
    return s_ord, y_ord, rho_ord, valid


def two_loop_direction(g: Array, s_ord: Array, y_ord: Array, rho_ord: Array,
                       valid: Array, gamma: Array) -> Array:
    """Batched L-BFGS two-loop recursion: returns H·g (NOT negated).

    All inputs carry a leading batch axis; history is chronological
    (slot 0 oldest).  Invalid slots are masked to no-ops, so restarts with
    different history lengths coexist in one call.
    """
    m = s_ord.shape[1]
    q = g
    alphas = []
    for jj in range(m - 1, -1, -1):     # newest -> oldest
        a = rho_ord[:, jj] * jnp.einsum("bd,bd->b", s_ord[:, jj], q)
        a = jnp.where(valid[:, jj], a, 0.0)
        q = q - a[:, None] * y_ord[:, jj]
        alphas.append(a)
    alphas = alphas[::-1]               # index by chronological jj
    r = gamma[:, None] * q
    for jj in range(m):                 # oldest -> newest
        b = rho_ord[:, jj] * jnp.einsum("bd,bd->b", y_ord[:, jj], r)
        b = jnp.where(valid[:, jj], b, 0.0)
        r = r + (alphas[jj] - b)[:, None] * s_ord[:, jj]
    return r


def inv_hessian_dense(state: LbfgsbState, m: int) -> Array:
    """Materialize the implied inverse Hessian H (B, D, D) from history.

    Used by the off-diagonal-artifact experiments: applying the two-loop
    recursion to the identity columns yields the dense matrix the recursion
    implicitly represents.
    """
    B, D = state.x.shape
    s_ord, y_ord, rho_ord, valid = _ordered_history(state, m)
    eye = jnp.eye(D, dtype=state.x.dtype)

    def col(e):
        gb = jnp.broadcast_to(e[None, :], (B, D))
        return two_loop_direction(gb, s_ord, y_ord, rho_ord, valid,
                                  state.gamma)
    cols = jax.vmap(col, out_axes=2)(eye)       # (B, D, D): H e_j in col j
    return cols


# ---------------------------------------------------------------------------
# the solver
# ---------------------------------------------------------------------------

def _init_state(fun_batched, x0, lower, upper, opts: LbfgsbOptions
                ) -> LbfgsbState:
    B, D = x0.shape
    x0 = _proj(x0, lower, upper)
    f0, g0 = fun_batched(x0)
    dt = x0.dtype
    zeros_hist = jnp.zeros((B, opts.m, D), dt)
    return LbfgsbState(
        x=x0, f=f0, g=g0,
        s_hist=zeros_hist, y_hist=zeros_hist,
        rho=jnp.zeros((B, opts.m), dt),
        start=jnp.zeros((B,), jnp.int32),
        length=jnp.zeros((B,), jnp.int32),
        gamma=jnp.ones((B,), dt),
        k=jnp.zeros((B,), jnp.int32),
        status=jnp.full((B,), RUNNING, jnp.int32),
        n_evals=jnp.ones((B,), jnp.int32),
        rounds=jnp.asarray(1, jnp.int32),
    )


def _check_initial_convergence(state: LbfgsbState, lower, upper,
                               opts: LbfgsbOptions) -> LbfgsbState:
    pg = projected_grad(state.x, state.g, lower, upper)
    done = jnp.max(jnp.abs(pg), axis=-1) <= opts.pgtol
    status = jnp.where(done, CONV_PGTOL, state.status)
    return state._replace(status=status.astype(jnp.int32))


def _step(fun_batched, lower, upper, opts: LbfgsbOptions,
          state: LbfgsbState) -> LbfgsbState:
    B, D = state.x.shape
    dt = state.x.dtype
    running = state.status == RUNNING                            # (B,)

    # ---- search direction -------------------------------------------------
    act = _active_mask(state.x, state.g, lower, upper, opts.bound_eps)
    gm = jnp.where(act, 0.0, state.g)
    s_ord, y_ord, rho_ord, valid = _ordered_history(state, opts.m)
    d = -two_loop_direction(gm, s_ord, y_ord, rho_ord, valid, state.gamma)
    d = jnp.where(act, 0.0, d)
    # descent check; fall back to projected steepest descent
    dg = jnp.einsum("bd,bd->b", d, gm)
    gnorm2 = jnp.einsum("bd,bd->b", gm, gm)
    bad = dg > -1e-12 * jnp.maximum(gnorm2, 1e-30)
    d = jnp.where(bad[:, None], -gm, d)
    dg = jnp.where(bad, -gnorm2, dg)

    # initial trial step: unit for QN steps, conservative on cold start
    dinf = jnp.max(jnp.abs(d), axis=-1)
    t0 = jnp.where((state.length == 0),
                   jnp.minimum(1.0, 1.0 / jnp.maximum(dinf, 1e-30)),
                   jnp.ones((B,), dt))

    # ---- projected backtracking Armijo line search (batched rounds) -------
    class LS(NamedTuple):
        t: Array; accepted: Array; x_new: Array; f_new: Array; g_new: Array
        tries: Array; rounds: Array; n_evals: Array

    def ls_cond(ls: LS):
        return jnp.any(running & ~ls.accepted & (ls.tries < opts.maxls))

    def ls_body(ls: LS):
        x_trial = _proj(state.x + ls.t[:, None] * d, lower, upper)
        # frozen/accepted rows re-evaluate their accepted point (lockstep);
        # their result is discarded by the mask below.
        f_t, g_t = fun_batched(x_trial)
        step_vec = x_trial - state.x
        gs = jnp.einsum("bd,bd->b", state.g, step_vec)
        armijo = f_t <= state.f + opts.armijo_c1 * gs
        # accept also if projection collapsed the step to ~zero (stuck)
        stuck = jnp.max(jnp.abs(step_vec), axis=-1) <= 1e-30
        newly = running & ~ls.accepted & (armijo | stuck)
        take = newly[:, None]
        evals = running & ~ls.accepted
        return LS(
            t=jnp.where(newly | ls.accepted, ls.t, ls.t * opts.ls_shrink),
            accepted=ls.accepted | newly | stuck,
            x_new=jnp.where(take, x_trial, ls.x_new),
            f_new=jnp.where(newly, f_t, ls.f_new),
            g_new=jnp.where(take, g_t, ls.g_new),
            tries=ls.tries + evals.astype(jnp.int32),
            rounds=ls.rounds + 1,
            n_evals=ls.n_evals + evals.astype(jnp.int32),
        )

    ls0 = LS(t=t0, accepted=~running, x_new=state.x, f_new=state.f,
             g_new=state.g, tries=jnp.zeros((B,), jnp.int32),
             rounds=jnp.asarray(0, jnp.int32),
             n_evals=jnp.zeros((B,), jnp.int32))
    ls = lax.while_loop(ls_cond, ls_body, ls0)

    ls_failed = running & ~ls.accepted
    # on failure keep the old iterate
    x_new = jnp.where(ls_failed[:, None], state.x, ls.x_new)
    f_new = jnp.where(ls_failed, state.f, ls.f_new)
    g_new = jnp.where(ls_failed[:, None], state.g, ls.g_new)

    # ---- curvature-pair update (masked, circular buffer) ------------------
    s_vec = x_new - state.x
    y_vec = g_new - state.g
    sy = jnp.einsum("bd,bd->b", s_vec, y_vec)
    yy = jnp.einsum("bd,bd->b", y_vec, y_vec)
    ss = jnp.einsum("bd,bd->b", s_vec, s_vec)
    curv_ok = sy > opts.curv_eps * jnp.sqrt(
        jnp.maximum(ss, 1e-300) * jnp.maximum(yy, 1e-300))
    do_push = running & ~ls_failed & curv_ok

    full = state.length == opts.m
    slot = (state.start + state.length % opts.m) % opts.m        # write pos
    onehot = jax.nn.one_hot(slot, opts.m, dtype=dt) * \
        do_push.astype(dt)[:, None]                              # (B, m)
    s_hist = state.s_hist * (1 - onehot)[:, :, None] + \
        onehot[:, :, None] * s_vec[:, None, :]
    y_hist = state.y_hist * (1 - onehot)[:, :, None] + \
        onehot[:, :, None] * y_vec[:, None, :]
    rho_new = jnp.where(do_push, 1.0 / jnp.where(do_push, sy, 1.0), 0.0)
    rho = state.rho * (1 - onehot) + onehot * rho_new[:, None]
    start = jnp.where(do_push & full, (state.start + 1) % opts.m,
                      state.start)
    length = jnp.where(do_push, jnp.minimum(state.length + 1, opts.m),
                       state.length)
    gamma = jnp.where(do_push, sy / jnp.maximum(yy, 1e-300), state.gamma)

    # ---- convergence tests -------------------------------------------------
    pg = projected_grad(x_new, g_new, lower, upper)
    conv_pg = jnp.max(jnp.abs(pg), axis=-1) <= opts.pgtol
    denom = jnp.maximum(jnp.maximum(jnp.abs(state.f), jnp.abs(f_new)), 1.0)
    conv_f = (opts.ftol > 0) & ((state.f - f_new) <= opts.ftol * denom)
    k_new = state.k + running.astype(jnp.int32)
    conv_it = k_new >= opts.maxiter

    status = state.status
    status = jnp.where(running & conv_pg, CONV_PGTOL, status)
    status = jnp.where(running & ~conv_pg & conv_f, CONV_FTOL, status)
    status = jnp.where(running & (status == RUNNING) & ls_failed,
                       CONV_LS_FAIL, status)
    status = jnp.where(running & (status == RUNNING) & conv_it,
                       CONV_MAXITER, status)

    keep = running[:, None]
    return LbfgsbState(
        x=jnp.where(keep, x_new, state.x),
        f=jnp.where(running, f_new, state.f),
        g=jnp.where(keep, g_new, state.g),
        s_hist=s_hist, y_hist=y_hist, rho=rho,
        start=start, length=length, gamma=gamma,
        k=k_new, status=status.astype(jnp.int32),
        n_evals=state.n_evals + ls.n_evals,
        rounds=state.rounds + ls.rounds,
    )


def _minimize_2d(fun_batched, x0, lower, upper,
                 options: LbfgsbOptions) -> LbfgsbResult:
    """The core (B, D) lockstep solve (see :func:`lbfgsb_minimize`)."""
    state = _init_state(fun_batched, x0, lower, upper, options)
    state = _check_initial_convergence(state, lower, upper, options)

    step = functools.partial(_step, fun_batched, lower, upper, options)
    state = lax.while_loop(
        lambda s: jnp.any(s.status == RUNNING), step, state)
    return LbfgsbResult(x=state.x, f=state.f, g=state.g, k=state.k,
                        status=state.status, n_evals=state.n_evals,
                        rounds=state.rounds, state=state)


def lbfgsb_minimize(
    fun_batched: Callable[[Array], Tuple[Array, Array]],
    x0: Array,
    lower: Array,
    upper: Array,
    options: LbfgsbOptions = LbfgsbOptions(),
) -> LbfgsbResult:
    """Minimize independent D-dimensional problems in lockstep.

    The batch may carry an *arbitrary leading shape*: ``x0`` of shape
    ``(*batch, D)`` runs ``prod(batch)`` problems through ONE
    ``lax.while_loop`` (the fleet-ask requirement: a ``(S, B, D)`` fleet
    of studies × restarts shares its QN iterations and line-search
    rounds, instead of vmapping S separate ``while_loop``s).  Every
    result leaf leads with ``batch`` again; ``rounds`` stays a scalar
    (rounds are shared by construction).

    Args:
      fun_batched: maps ``(*batch, D)`` → ``(batch values, (*batch, D)
        grads)``.  One call == one *batched evaluation round* in the
        paper's sense.
      x0: ``(*batch, D)`` initial points.
      lower/upper: broadcastable to ``x0.shape`` box bounds (±inf ok).
    """
    if x0.ndim < 2:
        raise ValueError(f"x0 must be (*batch, D), got {x0.shape}")
    lower = jnp.broadcast_to(jnp.asarray(lower, x0.dtype), x0.shape)
    upper = jnp.broadcast_to(jnp.asarray(upper, x0.dtype), x0.shape)
    if x0.ndim == 2:
        return _minimize_2d(fun_batched, x0, lower, upper, options)

    batch_shape, D = x0.shape[:-1], x0.shape[-1]

    def fun_flat(xf):
        f, g = fun_batched(xf.reshape(batch_shape + (D,)))
        return f.reshape(-1), g.reshape(-1, D)

    res = _minimize_2d(fun_flat, x0.reshape(-1, D),
                       lower.reshape(-1, D), upper.reshape(-1, D), options)

    def unflat(leaf):
        if leaf.ndim == 0:          # shared round counter
            return leaf
        return leaf.reshape(batch_shape + leaf.shape[1:])

    return jax.tree.map(unflat, res)


def lbfgsb_minimize_jit(fun_batched, x0, lower, upper,
                        options: LbfgsbOptions = LbfgsbOptions()):
    """jit-compiled entry point (options are static)."""
    @functools.partial(jax.jit, static_argnums=())
    def run(x0, lower, upper):
        return lbfgsb_minimize(fun_batched, x0, lower, upper, options)
    return run(x0, lower, upper)


# ---------------------------------------------------------------------------
# dense BFGS (for the unbounded off-diagonal-artifact appendix experiments)
# ---------------------------------------------------------------------------

class BfgsState(NamedTuple):
    x: Array; f: Array; g: Array
    hinv: Array          # (B, D, D)
    k: Array; status: Array


def bfgs_minimize(fun_batched, x0, *, maxiter=200, gtol=1e-8, maxls=25,
                  armijo_c1=1e-4, shrink=0.5) -> BfgsState:
    """Batched dense-BFGS (no bounds). Keeps the full (B, D, D) inverse
    Hessian so the artifact experiments can inspect it directly."""
    B, D = x0.shape
    dt = x0.dtype
    f0, g0 = fun_batched(x0)
    eye = jnp.broadcast_to(jnp.eye(D, dtype=dt), (B, D, D))
    st = BfgsState(x=x0, f=f0, g=g0, hinv=eye,
                   k=jnp.zeros((B,), jnp.int32),
                   status=jnp.where(
                       jnp.max(jnp.abs(g0), axis=-1) <= gtol,
                       CONV_PGTOL, RUNNING).astype(jnp.int32))

    def cond(s: BfgsState):
        return jnp.any(s.status == RUNNING)

    def body(s: BfgsState):
        running = s.status == RUNNING
        d = -jnp.einsum("bij,bj->bi", s.hinv, s.g)
        dg = jnp.einsum("bd,bd->b", d, s.g)
        bad = dg >= 0
        d = jnp.where(bad[:, None], -s.g, d)

        def ls_cond(c):
            t, acc, tries = c[0], c[1], c[5]
            return jnp.any(running & ~acc & (tries < maxls))

        def ls_body(c):
            t, acc, xn, fn, gn, tries = c
            xt = s.x + t[:, None] * d
            ft, gt = fun_batched(xt)
            gs = jnp.einsum("bd,bd->b", s.g, xt - s.x)
            ok = ft <= s.f + armijo_c1 * gs
            newly = running & ~acc & ok
            take = newly[:, None]
            return (jnp.where(newly | acc, t, t * shrink), acc | newly,
                    jnp.where(take, xt, xn), jnp.where(newly, ft, fn),
                    jnp.where(take, gt, gn),
                    tries + (running & ~acc).astype(jnp.int32))

        t0 = jnp.ones((B,), dt)
        c0 = (t0, ~running, s.x, s.f, s.g, jnp.zeros((B,), jnp.int32))
        t, acc, x_new, f_new, g_new, _ = lax.while_loop(ls_cond, ls_body, c0)
        fail = running & ~acc
        x_new = jnp.where(fail[:, None], s.x, x_new)
        f_new = jnp.where(fail, s.f, f_new)
        g_new = jnp.where(fail[:, None], s.g, g_new)

        sv = x_new - s.x
        yv = g_new - s.g
        sy = jnp.einsum("bd,bd->b", sv, yv)
        ok = running & ~fail & (sy > 1e-12)
        rho = 1.0 / jnp.where(ok, sy, 1.0)
        eyeD = jnp.eye(D, dtype=dt)
        V = eyeD[None] - rho[:, None, None] * \
            jnp.einsum("bi,bj->bij", sv, yv)
        h_upd = jnp.einsum("bik,bkl,bjl->bij", V, s.hinv, V) + \
            rho[:, None, None] * jnp.einsum("bi,bj->bij", sv, sv)
        hinv = jnp.where(ok[:, None, None], h_upd, s.hinv)

        conv = jnp.max(jnp.abs(g_new), axis=-1) <= gtol
        k_new = s.k + running.astype(jnp.int32)
        status = s.status
        status = jnp.where(running & conv, CONV_PGTOL, status)
        status = jnp.where(running & (status == RUNNING) & fail,
                           CONV_LS_FAIL, status)
        status = jnp.where(running & (status == RUNNING) &
                           (k_new >= maxiter), CONV_MAXITER, status)
        keep = running[:, None]
        return BfgsState(x=jnp.where(keep, x_new, s.x),
                         f=jnp.where(running, f_new, s.f),
                         g=jnp.where(keep, g_new, s.g),
                         hinv=hinv, k=k_new,
                         status=status.astype(jnp.int32))

    return lax.while_loop(cond, body, st)


def make_batched_value_and_grad(f_single: Callable[[Array], Array]):
    """Lift a single-point objective x:(D,)→() to the batched interface."""
    vg = jax.vmap(jax.value_and_grad(f_single))

    def fun_batched(xb):
        return vg(xb)
    return fun_batched
