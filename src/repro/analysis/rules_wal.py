"""wal-before-state: journal records must dominate the state they cover.

The WAL contract (ROADMAP: durability invariants) is *append the record,
then mutate*: recovery replays the journal through the normal paths, so
any host-state transition that lands before its record can be observed
by a crash that the journal never heard about.

Scope: only functions that *directly* contain a journal append — a call
to ``self._journal(...)`` or ``*.journal.append(...)`` — excluding
``__init__`` (constructors journal their own config record after field
setup by design).  Within such a function, every *tracked mutation* must
be dominated by a journal call on its control-flow path:

* attribute stores to journaled scalar state
  (``state``/``shed``/``parked``/``degraded``/``not_before``/``_rung``)
* destructive container ops (``pop``/``popleft``/``remove``/``clear``)
  on journaled containers (``xs``/``ys``/``tags``/``trials``/``queue``/
  ``_queue``/``_delayed``/``studies``)
* growth ops (``append``/``appendleft``/``extend``) on scheduler
  containers (``trials``/``queue``/``_queue``/``_delayed``) — but *not*
  on per-study observation lists, whose WAL lives in the caller's tell
  record
* slot installs (``blk.studies[slot] = ...``) and calls to the compound
  mutators ``self._evict`` / ``self._clear_slot``

Dominance is computed by a suite walk: a branch that terminates
(return/raise) does not propagate its journal flag past the statement;
loop bodies are checked but never propagate (they may run zero times).
An ``if <...journal...>:`` guard around the append itself (the optional-
journal idiom) counts as dominating the fall-through.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from .core import (Finding, ModuleInfo, Project, Rule, call_target,
                   dotted_name, last_segment)

SCALAR_ATTRS = {"state", "shed", "parked", "degraded", "not_before",
                "_rung"}
DESTRUCTIVE_OPS = {"pop", "popleft", "remove", "clear"}
DESTRUCTIVE_CONTAINERS = {"xs", "ys", "tags", "trials", "queue", "_queue",
                          "_delayed", "studies"}
GROWTH_OPS = {"append", "appendleft", "extend"}
GROWTH_CONTAINERS = {"trials", "queue", "_queue", "_delayed"}
SUBSCRIPT_CONTAINERS = {"studies"}
COMPOUND_MUTATORS = {"_evict", "_clear_slot"}


def is_journal_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    if isinstance(fn, ast.Attribute):
        if fn.attr == "_journal":
            return True
        if fn.attr == "append":
            base = last_segment(fn.value)
            if base is not None and "journal" in base:
                return True
    return False


def _stmt_has_journal(stmt: ast.stmt) -> bool:
    return any(is_journal_call(n) for n in ast.walk(stmt))


def _mutation_in_expr(node: ast.AST) -> Optional[Tuple[ast.AST, str]]:
    """First tracked mutation inside an expression tree (calls only)."""
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        tgt = call_target(n)
        if tgt in COMPOUND_MUTATORS:
            return n, f"call to {dotted_name(n.func) or tgt}()"
        if isinstance(n.func, ast.Attribute):
            recv = last_segment(n.func.value)
            if (tgt in DESTRUCTIVE_OPS and recv in DESTRUCTIVE_CONTAINERS):
                return n, f"{recv}.{tgt}() on journaled container"
            if tgt in GROWTH_OPS and recv in GROWTH_CONTAINERS:
                return n, f"{recv}.{tgt}() on journaled container"
    return None


def _mutations_in_stmt(stmt: ast.stmt) -> List[Tuple[ast.AST, str]]:
    out: List[Tuple[ast.AST, str]] = []
    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            elts = t.elts if isinstance(t, ast.Tuple) else [t]
            for el in elts:
                if isinstance(el, ast.Attribute) and el.attr in SCALAR_ATTRS:
                    out.append((el, f"store to .{el.attr}"))
                if (isinstance(el, ast.Subscript)
                        and isinstance(el.value, ast.Attribute)
                        and el.value.attr in SUBSCRIPT_CONTAINERS):
                    out.append((el, f"slot store to .{el.value.attr}[...]"))
        value = stmt.value
        if value is not None:
            m = _mutation_in_expr(value)
            if m:
                out.append(m)
    elif isinstance(stmt, ast.Expr):
        m = _mutation_in_expr(stmt.value)
        if m:
            out.append(m)
    return out


def _terminates(stmt: ast.stmt) -> bool:
    return isinstance(stmt, (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _journal_guard_test(test: ast.AST) -> bool:
    """``if self.journal is not None:`` / ``if journal:`` style guards."""
    for n in ast.walk(test):
        name = last_segment(n) if isinstance(n, (ast.Name, ast.Attribute)) \
            else None
        if name is not None and "journal" in name:
            return True
    return False


class WalBeforeStateRule(Rule):
    id = "wal-before-state"
    severity = "error"
    doc = ("journaled host-state mutations must be dominated by their "
           "journal append (WAL ordering)")

    def run(self, module: ModuleInfo, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name == "__init__":
                continue
            if not any(is_journal_call(n) for n in ast.walk(node)):
                continue
            fi = project.func_for_node(node)
            qual = fi.qualname if fi else node.name
            self._check_suite(node.body, False, module, qual, findings)
        return findings

    # returns (journaled_after, terminated)
    def _check_suite(self, stmts, journaled: bool, module: ModuleInfo,
                     qual: str, findings: List[Finding]
                     ) -> Tuple[bool, bool]:
        for stmt in stmts:
            if isinstance(stmt, ast.If):
                jb, tb = self._check_suite(stmt.body, journaled, module,
                                           qual, findings)
                jo, to = self._check_suite(stmt.orelse, journaled, module,
                                           qual, findings)
                if not stmt.orelse and _journal_guard_test(stmt.test):
                    # optional-journal idiom: treat the guarded append as
                    # covering the fall-through (journal=None disables
                    # durability wholesale, not the ordering)
                    journaled = journaled or jb
                else:
                    journaled = journaled or ((jb or tb) and (jo or to))
            elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                self._check_suite(stmt.body, journaled, module, qual,
                                  findings)
                self._check_suite(stmt.orelse, journaled, module, qual,
                                  findings)
            elif isinstance(stmt, ast.Try):
                jb, tb = self._check_suite(stmt.body, journaled, module,
                                           qual, findings)
                for h in stmt.handlers:
                    self._check_suite(h.body, journaled, module, qual,
                                      findings)
                self._check_suite(stmt.orelse, jb, module, qual, findings)
                jf, _ = self._check_suite(stmt.finalbody, journaled, module,
                                          qual, findings)
                journaled = journaled or jf
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                journaled, term = self._check_suite(stmt.body, journaled,
                                                    module, qual, findings)
                if term:
                    return journaled, True
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                continue                     # nested defs: separate scope
            else:
                if not journaled:
                    for mnode, desc in _mutations_in_stmt(stmt):
                        findings.append(module.finding(
                            self, mnode,
                            f"{desc} before its journal append — WAL "
                            f"record must dominate the state change",
                            func=qual))
                if _stmt_has_journal(stmt):
                    journaled = True
                if _terminates(stmt):
                    return journaled, True
        return journaled, False
