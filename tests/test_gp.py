"""GP substrate tests: posterior math, masked LML, padding exactness,
hyperparameter fit sanity, property-based invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.gp.fit import fit_gp, standardize
from repro.gp.gpr import (GPState, fit_gram, log_marginal_likelihood,
                          log_marginal_likelihood_masked, pad_gp, predict)
from repro.gp.kernels import KernelParams, gram, init_params, matern52


def _data(n=24, d=3, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(0, 1, (n, d)))
    y = jnp.sin(3 * X).sum(1) + 0.05 * jnp.asarray(
        rng.standard_normal(n))
    return X, y


def test_gram_spd_and_symmetric():
    X, _ = _data()
    p = init_params(X.shape[1])
    K = gram(X, p)
    np.testing.assert_allclose(K, K.T, atol=1e-12)
    w = np.linalg.eigvalsh(np.asarray(K))
    assert w.min() > 0


def test_posterior_interpolates_noiseless():
    X, y = _data(16)
    p = init_params(X.shape[1])._replace(
        log_noise=jnp.asarray(-14.0))
    gp = fit_gram(X, y, p)
    mean, var = predict(gp, X)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(y), atol=1e-4)
    assert float(jnp.max(var)) < 1e-4


def test_posterior_reverts_to_prior_far_away():
    X, y = _data(16)
    p = init_params(X.shape[1])
    gp = fit_gram(X, y, p)
    far = jnp.full((1, X.shape[1]), 100.0)
    mean, var = predict(gp, far)
    np.testing.assert_allclose(float(mean[0]), 0.0, atol=1e-8)
    np.testing.assert_allclose(float(var[0]), float(p.amplitude),
                               rtol=1e-6)


def test_masked_lml_equals_exact():
    X, y = _data(20)
    p = init_params(X.shape[1])
    exact = log_marginal_likelihood(X, y, p)
    n_pad = 12
    Xp = jnp.concatenate([X, jnp.full((n_pad, X.shape[1]), 1e6)
                          + jnp.arange(n_pad)[:, None]], 0)
    yp = jnp.concatenate([y, jnp.zeros(n_pad)])
    valid = jnp.arange(20 + n_pad) < 20
    masked = log_marginal_likelihood_masked(Xp, yp, valid, p)
    np.testing.assert_allclose(float(masked), float(exact), rtol=1e-10)


def test_padded_fit_predict_exact():
    """fit_gp's padded GPState predicts identically to an unpadded fit."""
    X, y = _data(21)          # deliberately not a bucket multiple
    gp_pad = fit_gp(X, y, n_restarts=1, pad_bucket=32)
    gp_exact = fit_gram(X, y, gp_pad.params)
    Xq = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (7, 3)))
    m1, v1 = predict(gp_pad, Xq)
    m2, v2 = predict(gp_exact, Xq)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-10)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-10)


def test_pad_gp_utility_exact():
    X, y = _data(18)
    p = init_params(X.shape[1])
    gp = fit_gram(X, y, p)
    gpp = pad_gp(gp, 32)
    Xq = jnp.asarray(np.random.default_rng(2).uniform(0, 1, (5, 3)))
    m1, v1 = predict(gp, Xq)
    m2, v2 = predict(gpp, Xq)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-10)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-10)


def test_fit_improves_lml():
    X, y = _data(32, seed=3)
    init = init_params(X.shape[1])
    gp = fit_gp(X, y, n_restarts=2)
    lml_init = log_marginal_likelihood(X, y, init)
    lml_fit = log_marginal_likelihood(X, y, gp.params)
    assert float(lml_fit) > float(lml_init)


def test_standardize():
    y = jnp.asarray([1.0, 2.0, 3.0, 10.0])
    ys, mu, sd = standardize(y)
    np.testing.assert_allclose(float(jnp.mean(ys)), 0.0, atol=1e-12)
    np.testing.assert_allclose(float(jnp.std(ys)), 1.0, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(3, 30))
def test_property_variance_nonnegative_and_bounded(seed, n):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(-2, 2, (n, 2)))
    y = jnp.asarray(rng.standard_normal(n))
    gp = fit_gram(X, y, init_params(2))
    Xq = jnp.asarray(rng.uniform(-3, 3, (16, 2)))
    _, var = predict(gp, Xq)
    assert float(jnp.min(var)) >= 0.0
    assert float(jnp.max(var)) <= float(gp.params.amplitude) + 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_matern_kernel_bounds(seed):
    """0 < k(x,x') ≤ σ², k(x,x) == σ²."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(-5, 5, (10, 4)))
    p = init_params(4)
    K = matern52(X, X, p)
    amp = float(p.amplitude)
    assert float(jnp.min(K)) > 0.0
    assert float(jnp.max(K)) <= amp * (1 + 1e-9)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(K)), amp, rtol=1e-6)
