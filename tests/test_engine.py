"""Evaluation-engine tests: bucketed pad-or-shrink scheduling, compile
accounting, q-batch joint acquisition, and the fused posterior backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coroutine as co
from repro.core.acquisition import logei_acq, qlogei_acq, qlogei_state
from repro.core.mso import MsoOptions, maximize_acqf
from repro.engine import EvalEngine, EvalPlan, bucket_ladder, fused_logei_acq
from repro.gp.gpr import fit_gram, pad_gp, with_kinv
from repro.gp.kernels import init_params
from repro.kernels.matern.ops import matern52_posterior_op
from repro.kernels.matern.ref import matern52_posterior_ref


def sphere_acq(state, X):
    del state
    return -jnp.sum((X - 0.5) ** 2, axis=tuple(range(1, X.ndim)))


@pytest.fixture(scope="module")
def gp50():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.uniform(0, 1, (50, 4)))
    y = jnp.asarray(np.sin(8 * np.asarray(X)).sum(1))
    # moderate incumbent: keeps LogEI in a numerically ordinary range
    # (an unfitted GP with best=max(y) pushes z < -25, where MC estimators
    # and f32 comparisons both measure nothing but the tail asymptotics)
    best = float(jnp.quantile(y, 0.3))
    return with_kinv(fit_gram(X, y, init_params(4))), best


# ------------------------------------------------------------------- plan
def test_bucket_ladder():
    assert bucket_ladder(10) == (1, 2, 4, 8, 10)
    assert bucket_ladder(8) == (1, 2, 4, 8)
    assert bucket_ladder(1) == (1,)


def test_plan_bucket_for():
    plan = EvalPlan.for_batch(10, 3)
    assert [plan.bucket_for(k) for k in (1, 2, 3, 5, 8, 9, 10)] == \
        [1, 2, 4, 8, 8, 10, 10]
    fixed = EvalPlan.for_batch(10, 3, bucketed=False)
    assert all(fixed.bucket_for(k) == 10 for k in range(1, 11))
    with pytest.raises(ValueError):
        plan.bucket_for(11)


# -------------------------------------------------- pad-or-shrink economy
def test_padded_eval_identical_to_unpadded():
    """Padding up to a bucket and slicing back must be bitwise invisible."""
    eng = EvalEngine(sphere_acq)
    plan = EvalPlan.for_batch(8, 3)
    be = eng.evaluator(None, plan)
    rng = np.random.default_rng(1)
    X8 = rng.uniform(0, 1, (8, 3))
    f8, g8 = be(X8)
    for k in (1, 2, 3, 5, 7):
        fk, gk = be(X8[:k])            # padded to bucket_for(k) internally
        np.testing.assert_array_equal(fk, f8[:k])
        np.testing.assert_array_equal(gk, g8[:k])


def test_bucketing_compile_economy():
    """A mixed-size run (the shrinking schedule) compiles once per bucket,
    not once per active-set size."""
    eng = EvalEngine(sphere_acq)
    plan = EvalPlan.for_batch(10, 3)
    be = eng.evaluator(None, plan)
    rng = np.random.default_rng(2)
    for k in (10, 9, 8, 7, 6, 5, 4, 3, 2, 1, 1, 2, 5, 10):
        be(rng.uniform(0, 1, (k, 3)))
    assert eng._eval_jit.n_compiles <= len(plan.buckets)
    # and the padded-row accounting is consistent
    assert eng.stats.n_points == 10 + 9 + 8 + 7 + 6 + 5 + 4 + 3 + 2 + 1 \
        + 1 + 2 + 5 + 10
    assert eng.stats.n_padded > 0
    assert set(eng.stats.bucket_rounds) <= set(plan.buckets)


def test_values_shares_cache_with_evaluator():
    """values() reuses the evaluator's jitted primitive: same shapes ⇒
    zero extra compiles, and it returns +acq (max scale)."""
    eng = EvalEngine(sphere_acq)
    plan = EvalPlan.for_batch(8, 3)
    be = eng.evaluator(None, plan)
    rng = np.random.default_rng(12)
    X = rng.uniform(0, 1, (8, 3))
    f_neg, _ = be(X)
    n0 = eng._eval_jit.n_compiles
    v_flat = eng.values(None, X.reshape(8, 3), plan=plan)   # flat + plan
    v_direct = eng.values(None, X)                          # already shaped
    assert eng._eval_jit.n_compiles == n0                   # cache hit
    np.testing.assert_allclose(v_flat, -f_neg)
    np.testing.assert_allclose(v_direct, -f_neg)


def test_lockstep_shares_engine_and_compiles_once():
    eng = EvalEngine(sphere_acq)
    x0 = np.random.default_rng(3).uniform(0, 1, (6, 3))
    for _ in range(3):
        res = maximize_acqf(sphere_acq, x0, 0.0, 1.0, strategy="dbe_vec",
                            options=MsoOptions(maxiter=50, pgtol=1e-8),
                            engine=eng)
    assert eng._vec_jit.n_compiles == 1
    np.testing.assert_allclose(res.best_x, 0.5, atol=1e-5)


def test_lockstep_surfaces_eval_economy_in_stats():
    """dbe_vec rounds/evals land in EngineStats (and thus BENCH rows):
    the fastest strategy must not report 0 evaluation work."""
    eng = EvalEngine(sphere_acq)
    x0 = np.random.default_rng(13).uniform(0, 1, (6, 3))
    res = maximize_acqf(sphere_acq, x0, 0.0, 1.0, strategy="dbe_vec",
                        options=MsoOptions(maxiter=50, pgtol=1e-8),
                        engine=eng)
    es = res.engine_stats
    assert es["n_rounds"] == res.n_rounds > 0
    assert es["n_points"] == int(np.sum(res.n_evals)) > 0
    # frozen-row evaluations are the lockstep analogue of padding waste
    assert es["n_padded"] == res.n_rounds * 6 - es["n_points"] >= 0
    assert es["bucket_rounds"].get(6) == res.n_rounds


# ------------------------------------------------ shrinking active set
def test_dbe_batch_sizes_non_increasing():
    """Converged restarts leave and never re-join: the evaluation batch
    shrinks monotonically (paper §4)."""
    eng = EvalEngine(sphere_acq)
    plan = EvalPlan.for_batch(6, 3)
    rng = np.random.default_rng(4)
    x0 = rng.uniform(0, 1, (6, 3))
    x0[0] = 0.5                       # converges instantly
    x0[1] = 0.499999                  # converges almost instantly
    out = co.run_dbe_coroutine(eng.evaluator(None, plan), x0,
                               np.zeros(3), np.ones(3),
                               m=10, maxiter=100, pgtol=1e-10)
    sizes = out.batch_sizes
    assert sizes[0] == 6
    assert sizes[-1] < 6
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))


# ------------------------------------------------------------- q-batch
def test_qlogei_reduces_to_logei_at_q1(gp50):
    """Smoothed MC qLogEI at q=1 tracks analytic LogEI to the smoothing/MC
    tolerance — the joint path is a strict generalization."""
    gp, best = gp50
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.uniform(0, 1, (16, 4)))
    la = logei_acq((gp, jnp.asarray(best)), X)
    state = qlogei_state(gp, best, 1, n_samples=4096, seed=0)
    qla = qlogei_acq(state, X[:, None, :])
    # MC + softmax smoothing: agreement is statistical, not bitwise, and
    # only where EI is non-negligible (a 4096-draw estimator cannot see
    # EI ~ e^-40; those points just need to stay strongly negative)
    head = np.asarray(la) > -5.0
    assert head.sum() >= 5
    err = np.abs(np.asarray(qla - la))
    assert float(err[head].max()) < 0.35, (qla, la)
    assert np.all(np.asarray(qla)[~head] < -2.0)


def test_maximize_acqf_joint_q2(gp50):
    """maximize_acqf q=2: joint candidates optimize, improve over their
    inits, and a joint pair beats duplicating the single best point."""
    gp, best = gp50
    q = 2
    state = qlogei_state(gp, best, q, n_samples=128, seed=0)
    rng = np.random.default_rng(6)
    x0 = rng.uniform(0, 1, (5, q, 4))
    # seed one restart with the single-point LogEI maximizer duplicated:
    # L-BFGS-B descends monotonically, so the joint optimum must end up
    # at least as good as the best duplicated single point
    r1 = maximize_acqf(logei_acq, x0[:, 0, :], 0.0, 1.0,
                       acq_state=(gp, jnp.asarray(best)), strategy="dbe",
                       options=MsoOptions(maxiter=80, pgtol=1e-6))
    x0[0] = r1.best_x[None, :].repeat(q, 0)
    init_vals = np.asarray(qlogei_acq(state, jnp.asarray(x0)))
    res = maximize_acqf(qlogei_acq, x0, 0.0, 1.0, acq_state=state,
                        strategy="dbe", q=q,
                        options=MsoOptions(maxiter=80, pgtol=1e-6))
    assert res.x.shape == (5, q, 4)
    assert res.best_x.shape == (q, 4)
    assert res.best_acq >= float(np.max(init_vals)) - 1e-9


def test_joint_q2_all_strategies_agree(gp50):
    gp, best = gp50
    state = qlogei_state(gp, best, 2, n_samples=64, seed=0)
    x0 = np.random.default_rng(7).uniform(0, 1, (4, 2, 4))
    init_best = float(np.max(np.asarray(qlogei_acq(state,
                                                   jnp.asarray(x0)))))
    bests = {}
    for s in ("seq", "dbe", "dbe_vec"):
        r = maximize_acqf(qlogei_acq, x0, 0.0, 1.0, acq_state=state,
                          strategy=s, q=2,
                          options=MsoOptions(maxiter=80, pgtol=1e-6))
        bests[s] = r.best_acq
        assert r.best_acq >= init_best - 1e-9, (s, r.best_acq, init_best)
    v = np.array(list(bests.values()))
    # same landscape, local optimizers: comparable, not identical
    assert np.max(v) - np.min(v) < 1.0, bests


# ----------------------------------------------------- fused posterior
def test_fused_posterior_matches_ref_interpret():
    """Pallas kernel (interpret mode) vs jnp oracle at equal precision."""
    rng = np.random.default_rng(8)
    for n, D, k in [(7, 3, 5), (50, 5, 33), (130, 8, 129)]:
        X = jnp.asarray(rng.uniform(0, 1, (n, D)), jnp.float32)
        y = jnp.asarray(np.sin(5 * np.asarray(X)).sum(1), jnp.float32)
        gp = with_kinv(fit_gram(X, y, init_params(D, jnp.float32),
                                jitter=1e-4))
        Xq = jnp.asarray(rng.uniform(0, 1, (k, D)), jnp.float32)
        ils = jnp.exp(-gp.params.log_lengthscale)
        args = (Xq, gp.x_train, gp.alpha, gp.kinv, ils,
                gp.params.amplitude)
        m_ref, v_ref = matern52_posterior_ref(*args)
        m_pal, v_pal = matern52_posterior_op(*args, backend="pallas",
                                             interpret=True)
        scale = float(jnp.max(jnp.abs(m_ref))) + 1.0
        np.testing.assert_allclose(np.asarray(m_pal) / scale,
                                   np.asarray(m_ref) / scale, atol=1e-5)
        np.testing.assert_allclose(np.asarray(v_pal), np.asarray(v_ref),
                                   atol=1e-5)


def test_fused_posterior_grad_matches_ref():
    """The custom VJP routes gradients through the oracle exactly."""
    rng = np.random.default_rng(9)
    X = jnp.asarray(rng.uniform(0, 1, (40, 4)))
    y = jnp.asarray(np.sin(6 * np.asarray(X)).sum(1))
    gp = with_kinv(fit_gram(X, y, init_params(4)))
    Xq = jnp.asarray(rng.uniform(0, 1, (9, 4)))
    ils = jnp.exp(-gp.params.log_lengthscale)
    args = (gp.x_train, gp.alpha, gp.kinv, ils, gp.params.amplitude)

    def val(f):
        def g(xq):
            m, v = f(xq, *args)
            # linear functional: unit cotangents, so the VJPs compare
            # exactly (a nonlinear readout would mix in the f32 forward)
            return jnp.sum(m) + jnp.sum(v)
        return g

    g_pal = jax.grad(val(lambda *a: matern52_posterior_op(
        *a, backend="pallas", interpret=True)))(Xq)
    g_ref = jax.grad(val(matern52_posterior_ref))(Xq)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=1e-10, atol=1e-12)


def test_fused_logei_acq_matches_xla_path(gp50):
    """The engine's fused LogEI backend == the classic Cholesky LogEI."""
    gp, best = gp50
    state = (gp, jnp.asarray(best))
    X = jnp.asarray(np.random.default_rng(10).uniform(0, 1, (12, 4)))
    a_x = logei_acq(state, X)
    a_f = fused_logei_acq("pallas_interpret")(state, X)
    # f32 kernel vs f64 Cholesky: log-scale tail values amplify the
    # variance's relative f32 error, hence rtol (not atol) dominates
    np.testing.assert_allclose(np.asarray(a_f), np.asarray(a_x),
                               rtol=1e-3, atol=1e-4)


def test_fused_backend_through_mso(gp50):
    """Full D-BE maximization on the fused backend lands on the same
    optimum as the xla backend."""
    gp, best = gp50
    state = (gp, jnp.asarray(best))
    x0 = np.random.default_rng(11).uniform(0, 1, (6, 4))
    opts = MsoOptions(maxiter=100, pgtol=1e-5)
    r_xla = maximize_acqf(logei_acq, x0, 0.0, 1.0, acq_state=state,
                          strategy="dbe", options=opts)
    r_fused = maximize_acqf(fused_logei_acq("pallas_interpret"), x0,
                            0.0, 1.0, acq_state=state, strategy="dbe",
                            options=opts)
    assert abs(r_fused.best_acq - r_xla.best_acq) < 1e-2


def test_pad_gp_extends_kinv(gp50):
    gp, _ = gp50
    gpp = pad_gp(gp, 64)
    assert gpp.kinv is not None
    n = gp.x_train.shape[0]
    np.testing.assert_allclose(np.asarray(gpp.kinv[:n, :n]),
                               np.asarray(gp.kinv))
    np.testing.assert_array_equal(np.asarray(gpp.kinv[n:, :n]), 0.0)
