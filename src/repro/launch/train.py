"""Training launcher: mesh setup, sharded init, checkpoint/restart,
preemption handling, elastic rescale.

Examples (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20

Fault-tolerance semantics:
  * SIGTERM/SIGUSR1 → checkpoint + clean exit (preemption).
  * restart with the same --ckpt-dir resumes from the latest step.
  * restarting under a different device count / mesh shape just works —
    checkpoints are unsharded global arrays (ckpt/manager.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager, install_sigterm_handler
from repro.configs import get_config
from repro.data.synth import DataConfig, synth_batch
from repro.distributed.sharding import Boxed, is_boxed, param_pspecs
from repro.launch.mesh import (make_production_mesh, make_smoke_mesh,
                               use_mesh)
from repro.launch.shapes import init_fn_for
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--weight-decay", type=float, default=0.1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "bf16", "int8_ef"))
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--mesh", default="none",
                    choices=("none", "smoke", "single", "multi"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.dtype:
        cfg = cfg.replace(dtype=args.dtype)
    cfg = cfg.replace(attn_chunk=min(cfg.attn_chunk, args.seq))

    opt_cfg = OptimConfig(lr=args.lr, weight_decay=args.weight_decay,
                          total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1),
                          grad_compression=args.grad_compression)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      seed=args.seed)

    mesh = None
    if args.mesh == "smoke":
        mesh = make_smoke_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    flag = install_sigterm_handler()
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def build_state():
        key = jax.random.PRNGKey(args.seed)
        params = init_fn_for(cfg)(key, cfg)
        return params, init_opt_state(params, opt_cfg)

    ctx = use_mesh(mesh) if mesh is not None else None
    if ctx is not None:
        ctx.__enter__()
    try:
        params, opt_state = build_state()
        start_step = 0
        if mgr is not None and mgr.latest_step() is not None:
            start_step = mgr.latest_step()
            state = mgr.restore(start_step,
                                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

        step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                          grad_accum=args.grad_accum),
                          donate_argnums=(0, 1))

        t_start = time.time()
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in synth_batch(cfg, dcfg, step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)

            if (step + 1) % args.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                tput = dcfg.global_batch * dcfg.seq_len * \
                    (step + 1 - start_step) / max(time.time() - t_start,
                                                  1e-9)
                print(f"[train] step={step + 1} loss={loss:.4f} "
                      f"gnorm={gn:.3f} tok/s={tput:,.0f}", flush=True)

            should_ckpt = mgr is not None and (
                (step + 1) % args.ckpt_every == 0 or flag.triggered
                or step + 1 == args.steps)
            if should_ckpt:
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         block=flag.triggered)
            if flag.triggered:
                print(f"[train] preempted at step {step + 1}; "
                      "checkpoint written, exiting")
                break
        if mgr is not None:
            mgr.wait()
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    return params


if __name__ == "__main__":
    main()
