"""AdamW (raw JAX, no optax) with ZeRO-1 state sharding and gradient
compression options.

Distributed-optimization tricks (DESIGN.md §6):

* **ZeRO-1** — optimizer moments get an *extra* "data"-axis sharding on
  their first shardable dim (params stay model-sharded/replicated as usual),
  cutting optimizer memory by the DP degree.
* **Gradient compression** — ``grad_compression``:
  - ``"bf16"``: backward collectives run in bf16 (halves DP all-reduce
    bytes — visible in the dry-run HLO as bf16 all-reduce operands);
  - ``"int8_ef"``: per-tensor int8 quantization with error-feedback
    residuals carried in the optimizer state (convergence-safe simulation
    of an int8 wire format; the quantize→psum→dequantize placement is a
    shard_map on real multi-host meshes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Boxed, get_abstract_mesh, is_boxed

Array = jax.Array


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    zero1: bool = True
    shard_grads: bool = True           # ZeRO-2-style grad sharding
    grad_compression: str = "none"     # none | bf16 | int8_ef


class AdamState(NamedTuple):
    step: Array
    mu: Any         # first moment (param-tree)
    nu: Any         # second moment
    ef: Any         # error-feedback residuals (or empty tuple)


def init_opt_state(params, cfg: OptimConfig) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    ef = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                      params) if cfg.grad_compression == "int8_ef" else ()
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros), ef=ef)


def lr_schedule(cfg: OptimConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _quantize_int8_ef(g: Array, ef: Array) -> Tuple[Array, Array]:
    """Error-feedback int8 round trip: returns (decompressed, new residual)."""
    gc = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gc)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gc - deq


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state: AdamState, cfg: OptimConfig
                  ) -> Tuple[Any, AdamState, dict]:
    step = state.step + 1
    lr = lr_schedule(cfg, step)

    if cfg.grad_compression == "int8_ef":
        new_ef = jax.tree.map(lambda g, e: _quantize_int8_ef(g, e)[1],
                              grads, state.ef)
        grads = jax.tree.map(lambda g, e: _quantize_int8_ef(g, e)[0],
                             grads, state.ef)
    else:
        new_ef = state.ef

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0

    # Distributed-optimizer discipline: do the whole Adam update in the
    # ZeRO-sharded domain (params dynamic-sliced down to the moment
    # sharding — cheap), and all-gather only the final bf16 params.  The
    # naive formulation makes GSPMD materialize f32 copies of the FULL
    # params/delta per leaf (≈3× param bytes of temps on the 34B/132B
    # train cells; see EXPERIMENTS.md §Perf).
    mesh = get_abstract_mesh()
    use_zero = (mesh is not None and not mesh.empty
                and "data" in getattr(mesh, "axis_names", ()))
    if use_zero:
        from repro.distributed.sharding import pspec as _pspec
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

        def _zspec(b: Boxed):
            base = _pspec(b.value.shape, b.axes, mesh.axis_names, sizes)
            return zero1_pspec(base, b.value.shape, mesh.axis_names, sizes)

        def _to_zero(b: Boxed):
            return jax.lax.with_sharding_constraint(b.value, _zspec(b))
    else:
        def _to_zero(b: Boxed):          # noqa: E306
            return b.value

    def upd(p_boxed, g_boxed, mu_boxed, nu_boxed):
        p = _to_zero(p_boxed)
        g = _to_zero(g_boxed).astype(jnp.float32) * clip
        mu, nu = mu_boxed.value, nu_boxed.value
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        nhat = nu2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        # new_p stays in the ZeRO-sharded domain; the jit out_shardings
        # boundary performs the single bf16 all-gather back to the param
        # layout (or none at all under FSDP, where the domains coincide).
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        ax = p_boxed.axes
        return Boxed(new_p, ax), Boxed(mu2, ax), Boxed(nu2, ax)

    def _map(i):
        return jax.tree.map(
            lambda p, g, mu, nu: upd(p, g, mu, nu)[i],
            params, grads, state.mu, state.nu, is_leaf=is_boxed)

    new_params = _map(0)
    new_mu = _map(1)
    new_nu = _map(2)
    new_state = AdamState(step=step, mu=new_mu, nu=new_nu, ef=new_ef)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state pspecs (extra data-axis sharding)
# ---------------------------------------------------------------------------

def zero1_pspec(param_spec, shape, mesh_axis_names, mesh_shape) -> Any:
    """Extend a param PartitionSpec with "data" on the first dim that is
    unsharded and divisible — classic ZeRO-1 under SPMD.  No-op when the
    spec already uses "data" (e.g. FSDP params)."""
    from jax.sharding import PartitionSpec as P
    if "data" not in mesh_axis_names:
        return param_spec

    def _axes(entry):
        if entry is None:
            return ()
        return entry if isinstance(entry, tuple) else (entry,)

    if any("data" in _axes(e) for e in param_spec):
        return param_spec
    dsize = mesh_shape.get("data", 1)
    spec = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (s, cur) in enumerate(zip(shape, spec)):
        if cur is None and dsize > 1 and s % dsize == 0:
            spec[i] = "data"
            break
    return P(*spec)


def constrain_grads_zero1(grads):
    """with_sharding_constraint the (Boxed) grad tree to ZeRO-sharded specs
    — GSPMD then reduce-scatters the DP gradient reduction instead of
    all-reducing and keeps only this device's optimizer shard live
    (ZeRO-2-style gradient sharding; the chameleon-34b fp32 grad
    accumulator does not fit HBM without this)."""
    from repro.distributed.sharding import pspec
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or "data" not in mesh.axis_names:
        return grads
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def one(b: Boxed) -> Boxed:
        base = pspec(b.value.shape, b.axes, mesh.axis_names, sizes)
        z = zero1_pspec(base, b.value.shape, mesh.axis_names, sizes)
        return Boxed(jax.lax.with_sharding_constraint(b.value, z), b.axes)

    return jax.tree.map(one, grads, is_leaf=is_boxed)
