"""Gaussian-process regression: Cholesky posterior + marginal likelihood.

The per-evaluation cost O(n² + nD) of `predict` is exactly the quantity the
paper's cost model (§4) says dominates MSO — which is why batching B query
points into one `predict` call (one (B,n) cross-kernel + one triangular
solve with B right-hand sides) is where D-BE's speedup comes from.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_solve, solve_triangular

from repro.gp.kernels import KernelParams, KERNELS, gram

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclass
class GPState:
    """Immutable fitted-GP state: everything `predict` needs.

    Registered as a pytree with ``kernel`` as static aux data, so a GPState
    can flow through jit boundaries as a traced argument (the compilation-
    discipline requirement of the MSO layer).

    ``kinv`` (K⁻¹, optional) backs the fused quadratic-form posterior used
    by the evaluation engine's Pallas hot path; build it with
    :func:`with_kinv`.  ``None`` keeps the classic Cholesky-solve path.
    """
    x_train: Array       # (n, D)
    y_train: Array       # (n,)  (standardized)
    params: KernelParams
    chol: Array          # (n, n) lower Cholesky of K + (σ_n²+jitter) I
    alpha: Array         # (n,)   K⁻¹ y
    kernel: str = "matern52"
    kinv: Optional[Array] = None   # (n, n) K⁻¹ for the fused posterior

    def tree_flatten(self):
        return ((self.x_train, self.y_train, self.params, self.chol,
                 self.alpha, self.kinv), self.kernel)

    @classmethod
    def tree_unflatten(cls, aux, children):
        *head, kinv = children
        return cls(*head, kernel=aux, kinv=kinv)


def fit_gram(x: Array, y: Array, params: KernelParams,
             kernel: str = "matern52", jitter: float = 1e-8) -> GPState:
    K = gram(x, params, kernel, jitter)
    L = jnp.linalg.cholesky(K)
    alpha = cho_solve((L, True), y)
    return GPState(x_train=x, y_train=y, params=params, chol=L,
                   alpha=alpha, kernel=kernel)


def with_kinv(gp: GPState) -> GPState:
    """Materialize K⁻¹ from the Cholesky factor (no-op if present).

    One extra O(n³) triangular solve pair per fit — same order as the
    Cholesky itself — in exchange for a posterior variance that is a pure
    quadratic form, which is what the fused Pallas kernel consumes.
    """
    if gp.kinv is not None:
        return gp
    n = gp.x_train.shape[0]
    eye = jnp.eye(n, dtype=gp.chol.dtype)
    kinv = cho_solve((gp.chol, True), eye)
    return GPState(x_train=gp.x_train, y_train=gp.y_train, params=gp.params,
                   chol=gp.chol, alpha=gp.alpha, kernel=gp.kernel,
                   kinv=kinv)


def cholesky_update(chol: Array, k_col: Array, k_diag: Array,
                    idx: Array) -> Tuple[Array, Array]:
    """Rank-one *append* update of a padded Cholesky factor — O(n²).

    ``chol`` is the (b, b) lower factor of ``blockdiag(K_n, I_pad)`` (the
    padded-fit layout: identity rows for pad slots).  A new observation
    enters at row ``idx`` (== n, the first pad slot); ``k_col`` is its
    masked cross-covariance against the b rows (zero at slots ≥ idx) and
    ``k_diag`` its prior variance + noise + jitter.  The bordered update

        l₁₂ = L⁻¹ k,   l₂₂ = √(k_diag − ‖l₁₂‖²)

    replaces the identity row at ``idx`` in place, so the result is again
    blockdiag-padded — no O(n³) refactorization.  ``idx`` may be traced
    (the fused ask program calls this with a dynamic observation count).

    Returns ``(chol_new, s)`` with ``s = k_diag − ‖l₁₂‖²`` the Schur
    complement: ``s ≤ 0`` (numerically impossible K) signals the caller
    to fall back to a full refit.
    """
    z = solve_triangular(chol, k_col, lower=True)
    s = k_diag - jnp.dot(z, z)
    l22 = jnp.sqrt(jnp.maximum(s, 1e-300))
    e = jax.nn.one_hot(idx, chol.shape[0], dtype=chol.dtype)
    # z is zero at idx (masked k_col ⇒ identity block solves to 0), so the
    # new row is z with l22 dropped onto the diagonal
    row = z + l22 * e
    chol_new = chol * (1.0 - e)[:, None] + e[:, None] * row[None, :]
    return chol_new, s


def kinv_update(kinv: Array, k_col: Array, s: Array, idx: Array) -> Array:
    """Bordered-inverse append matching :func:`cholesky_update` — O(n²).

    With ``w = K⁻¹k`` (padded: zero at slots ≥ idx) and Schur complement
    ``s``, the blockwise inverse of the grown matrix is

        [[K⁻¹ + wwᵀ/s,  −w/s],
         [−wᵀ/s,          1/s]]

    which, in the padded layout (identity at pad slots, including the old
    entry at ``idx``), collapses to one symmetric rank-one correction:
    ``K⁻¹ + (w−e)(w−e)ᵀ/s − eeᵀ``.
    """
    w = kinv @ k_col
    e = jax.nn.one_hot(idx, kinv.shape[0], dtype=kinv.dtype)
    t = w - e
    return kinv + jnp.outer(t, t) / s - jnp.outer(e, e)


def predict(gp: GPState, x_query: Array) -> Tuple[Array, Array]:
    """Posterior mean and variance at (q, D) query points → ((q,), (q,)).

    One batched call for all q points: this is the 'Batched Evaluation' of
    Algorithm 1 — the cross gram (q, n) is built once and both solves batch
    over q.
    """
    kfn = KERNELS[gp.kernel]
    k_star = kfn(x_query, gp.x_train, gp.params)          # (q, n)
    mean = k_star @ gp.alpha                              # O(q·n)
    v = solve_triangular(gp.chol, k_star.T, lower=True)   # (n, q)
    prior = gp.params.amplitude
    var = jnp.maximum(prior - jnp.sum(v * v, axis=0), 1e-16)
    return mean, var


def predict_joint(gp: GPState, x_query: Array,
                  jitter: float = 1e-10) -> Tuple[Array, Array]:
    """Joint posterior over a q-batch: ((q,) mean, (q, q) covariance).

    The q-batch acquisition path (joint qLogEI) needs cross-candidate
    covariances, not just the diagonal ``predict`` returns.  Cost per
    candidate block is O(q·n² + q²·n); the engine vmaps this over the k
    restarts so one batched call serves the whole active set.
    """
    kfn = KERNELS[gp.kernel]
    k_star = kfn(x_query, gp.x_train, gp.params)          # (q, n)
    mean = k_star @ gp.alpha
    v = solve_triangular(gp.chol, k_star.T, lower=True)   # (n, q)
    k_qq = kfn(x_query, x_query, gp.params)               # (q, q)
    cov = k_qq - v.T @ v
    q = x_query.shape[0]
    cov = cov + jitter * jnp.eye(q, dtype=cov.dtype)
    return mean, cov


def log_marginal_likelihood(x: Array, y: Array, params: KernelParams,
                            kernel: str = "matern52",
                            jitter: float = 1e-8) -> Array:
    """log p(y | X, θ) — the GP-fit objective (maximized)."""
    n = x.shape[0]
    K = gram(x, params, kernel, jitter)
    L = jnp.linalg.cholesky(K)
    alpha = cho_solve((L, True), y)
    return (-0.5 * jnp.dot(y, alpha)
            - jnp.sum(jnp.log(jnp.diagonal(L)))
            - 0.5 * n * jnp.log(2.0 * jnp.pi))


def log_marginal_likelihood_masked(x: Array, y: Array, valid: Array,
                                   params: KernelParams,
                                   kernel: str = "matern52",
                                   jitter: float = 1e-8) -> Array:
    """Masked LML over a padded training set.

    Rows with ``valid == 0`` are replaced by unit-variance independent
    pseudo-observations of 0: the padded gram is ``blockdiag(K_valid, I)``
    and ``y`` is zeroed there, so the result equals the exact LML of the
    valid subset (the identity block contributes nothing).  This lets the
    fit jit-compile once per *size bucket* instead of once per trial.
    """
    v = valid.astype(x.dtype)
    K = gram(x, params, kernel, jitter)
    mask2 = v[:, None] * v[None, :]
    K = K * mask2 + jnp.diag(1.0 - v)
    yv = y * v
    L = jnp.linalg.cholesky(K)
    alpha = cho_solve((L, True), yv)
    n_valid = jnp.sum(v)
    return (-0.5 * jnp.dot(yv, alpha)
            - jnp.sum(jnp.log(jnp.diagonal(L)) * v)
            - 0.5 * n_valid * jnp.log(2.0 * jnp.pi))


def pad_gp(gp: GPState, multiple: int = 32) -> GPState:
    """Pad the training set so the acqf closure compiles once per size
    bucket instead of once per trial.

    Exactness: padded α entries are 0 ⇒ mean unchanged; the Cholesky factor
    is extended block-diagonally with I and the padded cross-kernel columns
    hit zero α / identity rows ⇒ variance unchanged... *provided the padded
    cross-kernel columns are zero*, which we get by placing the fake points
    at +inf-like distance (1e6 offset) where Matérn/RBF underflow to 0.
    """
    n, d = gp.x_train.shape
    n_pad = (-n) % multiple
    if n_pad == 0:
        return gp
    dt = gp.x_train.dtype
    far = jnp.full((n_pad, d), 1e6, dt) + \
        jnp.arange(n_pad, dtype=dt)[:, None]
    x_p = jnp.concatenate([gp.x_train, far], 0)
    y_p = jnp.concatenate([gp.y_train, jnp.zeros((n_pad,), dt)], 0)
    alpha_p = jnp.concatenate([gp.alpha, jnp.zeros((n_pad,), dt)], 0)
    L_p = jnp.zeros((n + n_pad, n + n_pad), dt)
    L_p = L_p.at[:n, :n].set(gp.chol)
    L_p = L_p.at[n:, n:].set(jnp.eye(n_pad, dtype=dt))
    kinv_p = None
    if gp.kinv is not None:
        # blockdiag(K⁻¹, I): padded cross-kernel columns are 0 anyway, so
        # the identity block never contributes to a real query's variance
        kinv_p = jnp.zeros((n + n_pad, n + n_pad), dt)
        kinv_p = kinv_p.at[:n, :n].set(gp.kinv)
        kinv_p = kinv_p.at[n:, n:].set(jnp.eye(n_pad, dtype=dt))
    return GPState(x_train=x_p, y_train=y_p, params=gp.params,
                   chol=L_p, alpha=alpha_p, kernel=gp.kernel, kinv=kinv_p)
