"""LM assembly: init/forward/prefill/decode for all decoder-only families
(dense, moe, vlm-backbone, hybrid RG-LRU, ssm xLSTM).  Encoder-decoder lives
in `models/whisper.py`.

Layer stacking uses `lax.scan` over homogeneous runs (compile-time is the
scarce resource on the 1-core dry-run host): dense/moe scan all layers;
RecurrentGemma scans (rec, rec, attn) triples + a recurrent tail; xLSTM
scans groups of (7 mLSTM + 1 sLSTM).  Remat policy wraps the scanned body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import Boxed, box, constrain, is_boxed
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import xlstm as XL
from repro.models.config import ModelConfig

Array = jax.Array


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layers; prepend a (layers) axis to Boxed axes."""
    keys = jax.random.split(key, n)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree.map(lambda b: Boxed(b.value, (None,) + b.axes),
                        stacked, is_leaf=is_boxed)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# per-family single-block init/apply
# ---------------------------------------------------------------------------

def _init_dense_block(cfg: ModelConfig, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        p = {
            "attn_norm": L.init_norm(cfg, dtype),
            "attn": L.init_attention(k1, cfg, dtype),
            "mlp_norm": L.init_norm(cfg, dtype),
        }
        if cfg.is_moe:
            p["moe"] = MOE.init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(k2, cfg, dtype)
        return p
    return init


def _apply_dense_block(p, cfg: ModelConfig, x, positions, cache=None,
                       cache_index=None):
    h = L.apply_norm(p["attn_norm"], x, cfg.norm)
    a, new_cache = L.apply_attention(
        p["attn"], cfg, h, positions, window=0,
        cache=cache, cache_index=cache_index)
    x = x + a
    h = L.apply_norm(p["mlp_norm"], x, cfg.norm)
    if cfg.is_moe:
        m, aux = MOE.apply_moe(p["moe"], cfg, h)
    else:
        m, aux = L.apply_mlp(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    return x + m, new_cache, aux


def _init_rg_block(cfg: ModelConfig, dtype, kind: str):
    def init(key):
        k1, k2 = jax.random.split(key)
        p = {
            "mix_norm": L.init_norm(cfg, dtype),
            "mlp_norm": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(k2, cfg, dtype),
        }
        if kind == "attn":
            p["attn"] = L.init_attention(k1, cfg, dtype)
        else:
            p["rec"] = RG.init_recurrent_block(k1, cfg, dtype)
        return p
    return init


def _apply_rg_block(p, cfg: ModelConfig, x, positions, kind: str,
                    state=None, cache_index=None):
    h = L.apply_norm(p["mix_norm"], x, cfg.norm)
    if kind == "attn":
        a, new_state = L.apply_attention(
            p["attn"], cfg, h, positions, window=cfg.window,
            cache=state, cache_index=cache_index)
    else:
        a, new_state = RG.apply_recurrent_block(p["rec"], cfg, h, state)
    x = x + a
    h = L.apply_norm(p["mlp_norm"], x, cfg.norm)
    return x + L.apply_mlp(p["mlp"], cfg, h), new_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    k_emb, k_blocks, k_tail = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(k_emb, cfg, dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stack_init(_init_dense_block(cfg, dtype),
                                       k_blocks, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        n_triples = n_attn
        n_tail = cfg.n_layers - n_triples * cfg.attn_every

        def init_triple(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "rec1": _init_rg_block(cfg, dtype, "rec")(k1),
                "rec2": _init_rg_block(cfg, dtype, "rec")(k2),
                "attn": _init_rg_block(cfg, dtype, "attn")(k3),
            }
        params["triples"] = _stack_init(init_triple, k_blocks, n_triples)
        if n_tail:
            params["tail"] = _stack_init(
                _init_rg_block(cfg, dtype, "rec"), k_tail, n_tail)
    elif cfg.family == "ssm":
        n_groups = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1

        def init_group(k):
            k1, k2 = jax.random.split(k)
            return {
                "mlstm": _stack_init(
                    lambda kk: XL.init_mlstm_block(kk, cfg, dtype), k1, n_m),
                "slstm": XL.init_slstm_block(k2, cfg, dtype),
            }
        params["groups"] = _stack_init(init_group, k_blocks, n_groups)
    else:
        raise ValueError(f"init_params: family {cfg.family} not handled here")
    return params


# ---------------------------------------------------------------------------
# forward (training / no-cache)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: Array,
            embeddings: Optional[Array] = None) -> Tuple[Array, Array]:
    """→ (final hidden (B,S,D), moe aux loss).  ``embeddings`` overrides
    token lookup for stub frontends."""
    if embeddings is None:
        x = L.embed_tokens(params["embed"], tokens)
    else:
        x = embeddings
    if cfg.seq_shard:
        x = constrain(x, "batch", "seq_sp", None)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, p_layer):
            h, aux = carry
            h2, _, a = _apply_dense_block(p_layer, cfg, h, positions)
            if cfg.seq_shard:
                # Megatron-SP: the residual stream (and therefore the
                # remat-saved scan carry) lives sequence-sharded over the
                # model axis; GSPMD splits each TP all-reduce into the
                # all-gather/reduce-scatter pair around it.
                h2 = constrain(h2, "batch", "seq_sp", None)
            return (h2, aux + a), None
        (x, aux), _ = lax.scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    elif cfg.family == "hybrid":
        def body(carry, p_tri):
            h = carry
            h, _ = _apply_rg_block(p_tri["rec1"], cfg, h, positions, "rec")
            h, _ = _apply_rg_block(p_tri["rec2"], cfg, h, positions, "rec")
            h, _ = _apply_rg_block(p_tri["attn"], cfg, h, positions, "attn")
            return h, None
        x, _ = lax.scan(_remat(body, cfg), x, params["triples"])
        if "tail" in params:
            def tail_body(carry, p_layer):
                h, _ = _apply_rg_block(p_layer, cfg, carry, positions, "rec")
                return h, None
            x, _ = lax.scan(_remat(tail_body, cfg), x, params["tail"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "ssm":
        def group_body(carry, p_group):
            h = carry

            def m_body(c, p_layer):
                y, _ = XL.apply_mlstm_block(p_layer, cfg, c)
                return c + y, None
            h, _ = lax.scan(m_body, h, p_group["mlstm"])
            y, _ = XL.apply_slstm_block(p_group["slstm"], cfg, h)
            return h + y, None
        x, _ = lax.scan(_remat(group_body, cfg), x, params["groups"])
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.family in ("dense", "moe", "vlm") and not cfg.is_moe:
        aux = jnp.zeros((), jnp.float32)
    return x, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(params, cfg: ModelConfig, hidden: Array,
                  targets: Array) -> Array:
    """Vocab-sharded softmax CE.  The (B,S,V) logits stay sharded
    (batch→data, vocab→model); reductions over V partition into per-shard
    reductions + scalar collectives — the full-logits all-gather never
    happens (DESIGN.md §6)."""
    logits = L.lm_logits(params["embed"], cfg, hidden).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    lmax = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - lmax), -1)) + lmax[..., 0]
    onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=jnp.float32)
    onehot = constrain(onehot, "batch", None, "vocab")
    true_logit = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - true_logit)


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, Array]) -> Array:
    hidden, aux = forward(params, cfg, batch["tokens"],
                          embeddings=batch.get("embeddings"))
    loss = cross_entropy(params, cfg, hidden, batch["targets"])
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-layer decode state matching the scan layouts."""
    dtype = _dtype(cfg)

    def rep(tree, n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)

    if cfg.family in ("dense", "moe", "vlm"):
        one = L.init_attn_cache(cfg, batch, max_len, dtype)
        return rep(one, cfg.n_layers)
    if cfg.family == "hybrid":
        n_triples = cfg.n_layers // cfg.attn_every
        n_tail = cfg.n_layers - n_triples * cfg.attn_every
        tri = {
            "rec1": RG.init_recurrent_state(cfg, batch, dtype),
            "rec2": RG.init_recurrent_state(cfg, batch, dtype),
            "attn": L.init_attn_cache(cfg, batch, max_len, dtype,
                                      window=cfg.window),
        }
        out = {"triples": rep(tri, n_triples)}
        if n_tail:
            out["tail"] = rep(RG.init_recurrent_state(cfg, batch, dtype),
                              n_tail)
        return out
    if cfg.family == "ssm":
        n_groups = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        grp = {
            "mlstm": rep(XL.init_mlstm_state(cfg, batch, dtype), n_m),
            "slstm": XL.init_slstm_state(cfg, batch),
        }
        return {"groups": rep(grp, n_groups)}
    raise ValueError(cfg.family)


def reset_slot(cfg: ModelConfig, cache, slot: int):
    """Zero one batch slot of a decode cache (continuous-batching admission).

    Attention caches get their per-slot positions invalidated (−1) so stale
    entries from the previous occupant can never pass the position mask;
    recurrent/ssm states zero out.  Batch axis: 2 for the doubly-stacked
    mLSTM leaves, 1 for everything else (layer-stacked).
    """
    def fix(path, leaf):
        axis = 2 if any(getattr(p, "key", None) == "mlstm" for p in path) \
            else 1
        idx = (slice(None),) * axis + (slot,)
        is_pos = getattr(path[-1], "key", None) == "pos"
        val = -jnp.ones_like(leaf[idx]) if is_pos \
            else jnp.zeros_like(leaf[idx])
        return leaf.at[idx].set(val)

    return jax.tree_util.tree_map_with_path(fix, cache)


def decode_step(params, cfg: ModelConfig, tokens: Array, cache,
                position) -> Tuple[Array, Any]:
    """One serving step.  tokens: (B, 1) int32; position: () or (B,) int32
    index of this token in each sequence (vector form = continuous
    batching).  Returns (logits (B, V), new cache)."""
    x = L.embed_tokens(params["embed"], tokens)
    B = x.shape[0]
    pos_arr = jnp.asarray(position, jnp.int32)
    if pos_arr.ndim == 0:
        positions = jnp.broadcast_to(pos_arr[None, None], (B, 1))
    else:
        positions = pos_arr[:, None]

    if cfg.family in ("dense", "moe", "vlm"):
        # The stacked KV cache rides in the scan CARRY and is updated with
        # dynamic_update_index — XLA aliases the while-loop carry in place,
        # so exactly ONE cache copy is live (scan xs/ys would double-buffer
        # the multi-GiB cache; see EXPERIMENTS.md §Perf decode iteration).
        def body(carry, inp):
            h, ck, cv, cpos = carry
            p_layer, li = inp
            c_layer = {
                "k": lax.dynamic_index_in_dim(ck, li, 0, keepdims=False),
                "v": lax.dynamic_index_in_dim(cv, li, 0, keepdims=False),
                "pos": lax.dynamic_index_in_dim(cpos, li, 0,
                                                keepdims=False),
            }
            h2, nc, _ = _apply_dense_block(p_layer, cfg, h, positions,
                                           cache=c_layer,
                                           cache_index=position)
            ck = lax.dynamic_update_index_in_dim(ck, nc["k"], li, 0)
            cv = lax.dynamic_update_index_in_dim(cv, nc["v"], li, 0)
            cpos = lax.dynamic_update_index_in_dim(cpos, nc["pos"], li, 0)
            return (h2, ck, cv, cpos), None

        n_layers = cache["pos"].shape[0]
        (x, ck, cv, cpos), _ = lax.scan(
            body, (x, cache["k"], cache["v"], cache["pos"]),
            (params["blocks"], jnp.arange(n_layers)))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    elif cfg.family == "hybrid":
        def body(h, inp):
            p_tri, c_tri = inp
            h, s1 = _apply_rg_block(p_tri["rec1"], cfg, h, positions, "rec",
                                    state=c_tri["rec1"])
            h, s2 = _apply_rg_block(p_tri["rec2"], cfg, h, positions, "rec",
                                    state=c_tri["rec2"])
            h, ca = _apply_rg_block(p_tri["attn"], cfg, h, positions, "attn",
                                    state=c_tri["attn"],
                                    cache_index=position)
            return h, {"rec1": s1, "rec2": s2, "attn": ca}
        x, new_tri = lax.scan(body, x, (params["triples"],
                                        cache["triples"]))
        new_cache = {"triples": new_tri}
        if "tail" in params:
            def tail_body(h, inp):
                p_layer, c_layer = inp
                h, s = _apply_rg_block(p_layer, cfg, h, positions, "rec",
                                       state=c_layer)
                return h, s
            x, new_tail = lax.scan(tail_body, x, (params["tail"],
                                                  cache["tail"]))
            new_cache["tail"] = new_tail
    elif cfg.family == "ssm":
        def group_body(h, inp):
            p_group, c_group = inp

            def m_body(c, minp):
                p_layer, s_layer = minp
                y, ns = XL.apply_mlstm_block(p_layer, cfg, c, state=s_layer,
                                             decode=True)
                return c + y, ns
            h, new_m = lax.scan(m_body, h, (p_group["mlstm"],
                                            c_group["mlstm"]))
            y, new_s = XL.apply_slstm_block(p_group["slstm"], cfg, h,
                                            state=c_group["slstm"])
            return h + y, {"mlstm": new_m, "slstm": new_s}
        x, new_groups = lax.scan(group_body, x, (params["groups"],
                                                 cache["groups"]))
        new_cache = {"groups": new_groups}
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.lm_logits(params["embed"], cfg, x)[:, 0, :]
    return logits, new_cache
