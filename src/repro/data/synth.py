"""Deterministic synthetic data pipeline (host-sharded, restart-stable).

Batches are a pure function of ``(seed, step)`` — a restarted job resumes at
step k and sees exactly the data it would have seen, with no data-loader
state in the checkpoint.  Multi-host: each process materializes only its
``process_index`` slice of the global batch (standard jax.distributed
convention); on this single-process container that's the whole batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng((cfg.seed * 1_000_003 + step) % (2**63))


def synth_batch(model_cfg: ModelConfig, cfg: DataConfig,
                step: int) -> Dict[str, np.ndarray]:
    """One global batch.  LM: markov-ish token stream (so loss can fall);
    enc-dec adds stub frames."""
    rng = _rng_for(cfg, step)
    B, S = cfg.global_batch, cfg.seq_len
    V = model_cfg.vocab_size

    # cheap structured stream: mixture of a drifting base + noise, so a
    # model can actually learn something during the example run
    base = rng.integers(0, V, (B, 1))
    drift = np.cumsum(rng.integers(0, 7, (B, S + 1)), axis=1)
    toks = ((base + drift) % V).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    if model_cfg.family == "encdec":
        S_enc = max(int(S * model_cfg.enc_seq_fraction), 8)
        batch["frames"] = rng.standard_normal(
            (B, S_enc, model_cfg.d_model)).astype(np.float32) * 0.02
    return batch


def batch_iterator(model_cfg: ModelConfig, cfg: DataConfig,
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synth_batch(model_cfg, cfg, step)
        step += 1
