"""Deterministic fault injection for the fleet's robustness layer.

The :class:`FaultInjector` duck-types the three chaos hooks the
production code exposes (``StudyJournal``, ``FleetEngine``,
``AskEngine`` all take a ``fault_injector=``):

* ``should_kill(seq)`` — the journal calls this before each append; when
  it fires, the journal writes a deliberately *partial* record (exactly
  the on-disk state a real ``kill -9`` mid-append leaves), fsyncs it,
  and raises :class:`repro.bo.journal.InjectedCrash`.
* ``incr_ok(ok, sids)`` — veto the incremental rank-one update's health
  flag, forcing the exactness fallback (full refit) deterministically.
* ``full_ok(ok, sids)`` — mark a full MAP refit unhealthy, forcing the
  quarantine → retry → park path deterministically.
* ``full_delay(sids)`` / ``tell_delay()`` — deterministic *latency*
  injection: report how many (virtual) seconds a full refit / a tell
  should appear to take.  The caller charges the delay to its sleep
  hook, which under a :class:`VirtualClock` advances simulated time
  instead of wall-clocking — so service timeout, backoff, and watchdog
  paths are testable without real sleeps or flaky wall-clock margins.
* ``ask_ok(study)`` — veto an ask dispatch at the service layer
  (``serve/bo_service.py``), simulating a transient refit/serve failure
  so the bounded-backoff retry path is exercised deterministically.

All hooks are host-side: an injector changes scheduling decisions, never
traced code, so the compile-economy invariants must hold under chaos.

The injector is deliberately one-shot / budgeted: a crash fires once
(real processes die once), and the ok vetoes decrement per-study budgets
so a test can script "study 1's next two full refits are unhealthy"
exactly.  ``sids`` may contain ``None`` entries — idle fleet slots, or
the solo ``AskEngine`` (which has no study id); budget vetoes keyed on
``None`` target those.
"""
from typing import Dict, Hashable, Optional, Tuple

import numpy as np


class VirtualClock:
    """Deterministic time source for service/robustness tests.

    Duck-types the pair the production code takes (``now()`` like
    ``time.monotonic``, ``sleep()`` like ``time.sleep``) but only ever
    advances when told: real wall time never leaks in, so deadline,
    backoff, and watchdog behavior is exactly reproducible."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)
        self.n_sleeps = 0
        self.slept_s = 0.0

    def now(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        dt = max(0.0, float(dt))
        self.t += dt
        self.n_sleeps += 1
        self.slept_s += dt

    def advance(self, dt: float) -> None:
        self.t += float(dt)


class FaultInjector:
    """Scriptable chaos: journal kills + refit-health vetoes + latency.

    Parameters
    ----------
    kill_at_seq:
        Journal sequence number at which to simulate a process kill
        (one-shot: fires on the first append with ``seq >= kill_at_seq``
        and then disarms, so a recovered run using the same injector
        keeps running).
    incr_fail:
        ``{sid: budget}`` — veto up to ``budget`` healthy incremental
        ``ok`` flags for that study (``None`` targets the solo
        AskEngine / anonymous slots).
    full_fail:
        ``{sid: budget}`` — mark up to ``budget`` full refits for that
        study unhealthy.
    full_latency:
        ``{sid: (seconds, budget)}`` — the study's next ``budget`` full
        refits report an extra ``seconds`` of (virtual) latency through
        ``full_delay``.
    tell_latency:
        ``(seconds, budget)`` — the next ``budget`` tells report an
        extra ``seconds`` of (virtual) latency through ``tell_delay``.
    ask_fail:
        ``{study: budget}`` — the service treats that study's next
        ``budget`` ask dispatches as transient failures (retry path).
    """

    def __init__(self, *, kill_at_seq: Optional[int] = None,
                 incr_fail: Optional[Dict[Hashable, int]] = None,
                 full_fail: Optional[Dict[Hashable, int]] = None,
                 full_latency: Optional[
                     Dict[Hashable, Tuple[float, int]]] = None,
                 tell_latency: Optional[Tuple[float, int]] = None,
                 ask_fail: Optional[Dict[Hashable, int]] = None):
        self.kill_at_seq = kill_at_seq
        self.incr_fail = dict(incr_fail or {})
        self.full_fail = dict(full_fail or {})
        self.full_latency = {k: list(v)
                             for k, v in (full_latency or {}).items()}
        self.tell_latency = list(tell_latency) if tell_latency else None
        self.ask_fail = dict(ask_fail or {})
        self.n_kills = 0
        self.n_incr_vetoed = 0
        self.n_full_vetoed = 0
        self.n_full_delays = 0
        self.n_tell_delays = 0
        self.n_ask_vetoed = 0
        self.injected_delay_s = 0.0

    # ------------------------------------------------------ journal hook
    def should_kill(self, seq: int) -> bool:
        if self.kill_at_seq is not None and seq >= self.kill_at_seq:
            self.kill_at_seq = None          # one-shot: processes die once
            self.n_kills += 1
            return True
        return False

    # ------------------------------------------------- refit-health hooks
    def _veto(self, budgets: Dict[Hashable, int], ok: np.ndarray,
              sids) -> np.ndarray:
        ok = np.array(ok)
        for i, sid in enumerate(sids):
            if ok[i] and budgets.get(sid, 0) > 0:
                ok[i] = False
                budgets[sid] -= 1
        return ok

    def incr_ok(self, ok, sids) -> np.ndarray:
        before = int(np.sum(np.asarray(ok)))
        out = self._veto(self.incr_fail, ok, sids)
        self.n_incr_vetoed += before - int(np.sum(out))
        return out

    def full_ok(self, ok, sids) -> np.ndarray:
        before = int(np.sum(np.asarray(ok)))
        out = self._veto(self.full_fail, ok, sids)
        self.n_full_vetoed += before - int(np.sum(out))
        return out

    # ---------------------------------------------------- latency hooks
    def full_delay(self, sids) -> float:
        """Virtual seconds this full-refit launch should appear to take
        (summed over the batched studies with latency budget left)."""
        total = 0.0
        for sid in sids:
            ent = self.full_latency.get(sid)
            if ent is not None and ent[1] > 0:
                total += ent[0]
                ent[1] -= 1
                self.n_full_delays += 1
        self.injected_delay_s += total
        return total

    def tell_delay(self) -> float:
        """Virtual seconds the next tell should appear to take."""
        ent = self.tell_latency
        if ent is not None and ent[1] > 0:
            ent[1] -= 1
            self.n_tell_delays += 1
            self.injected_delay_s += ent[0]
            return ent[0]
        return 0.0

    # ------------------------------------------------- service ask hook
    def ask_ok(self, study) -> bool:
        """False: the service must treat this dispatch as a transient
        failure (and retry with backoff)."""
        if self.ask_fail.get(study, 0) > 0:
            self.ask_fail[study] -= 1
            self.n_ask_vetoed += 1
            return False
        return True
