"""Acquisition functions — numerically stable LogEI (Ament et al. 2023),
EI, and UCB — plus the batched-evaluation closure used by every MSO
strategy.

The paper's experiment setting (§5): LogEI over a GP with Matérn-5/2,
optimized by L-BFGS-B MSO.  ``make_logei`` returns the `(k, D) → (k,)`
batched acquisition the MSO drivers consume.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.gp.gpr import GPState, predict

Array = jax.Array

_C1 = 0.5 * math.log(2.0 * math.pi)          # log √(2π)
_INV_SQRT2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _log_phi(z):
    return -0.5 * z * z - _C1


_BRANCH = -25.0     # direct f64 eval is cancellation-safe above this


def log_h(z: Array) -> Array:
    """log(φ(z) + z·Φ(z)) — the LogEI kernel, stable over all z.

    Branches (double-where guarded so gradients stay finite):
      z > -25  : direct  log(φ(z) + zΦ(z)) — the cancellation error is
                 ~eps·φ/h ≈ eps·z², still ≤1e-12 relative at z=-25 (f64);
      z ≤ -25  : asymptotic from Φ(z) ~ φ(z)/(−z)·Σ(−1)ᵏ(2k−1)!!/z²ᵏ:
                 log h = log φ − 2·log|z| + log1p(−3u + 15u² − 105u³),
                 u = 1/z² (next term 945u⁴ ≤ 6e-9 at the branch point).
    """
    z_safe_hi = jnp.maximum(z, _BRANCH)         # direct-branch input
    phi = jnp.exp(_log_phi(z_safe_hi))
    # erfc keeps Φ relatively accurate in the far tail (0.5·(1+erf) has
    # only absolute accuracy there, which the φ+zΦ cancellation amplifies)
    Phi = 0.5 * jax.lax.erfc(-z_safe_hi / jnp.sqrt(2.0).astype(z.dtype))
    direct_arg = jnp.maximum(phi + z_safe_hi * Phi, 1e-300)
    direct = jnp.log(direct_arg)

    z_safe_lo = jnp.minimum(z, _BRANCH)         # asymptotic-branch input
    u = 1.0 / (z_safe_lo * z_safe_lo)
    asym = (_log_phi(z_safe_lo) - 2.0 * jnp.log(-z_safe_lo)
            + jnp.log1p(-3.0 * u + 15.0 * u * u - 105.0 * u * u * u))
    return jnp.where(z > _BRANCH, direct, asym)


def log_ei(mean: Array, var: Array, best: Array) -> Array:
    """log E[max(0, μ − best)] under N(μ, σ²) — maximization convention."""
    sigma = jnp.sqrt(var)
    z = (mean - best) / sigma
    return log_h(z) + 0.5 * jnp.log(var)


def ei(mean: Array, var: Array, best: Array) -> Array:
    sigma = jnp.sqrt(var)
    z = (mean - best) / sigma
    phi = jnp.exp(_log_phi(z))
    Phi = 0.5 * jax.lax.erfc(-z / jnp.sqrt(2.0).astype(z.dtype))
    return sigma * (phi + z * Phi)


def ucb(mean: Array, var: Array, beta: float = 2.0) -> Array:
    return mean + beta * jnp.sqrt(var)


AcqBatched = Callable[[Array], Array]   # (k, D) -> (k,)


def logei_acq(state, xb: Array) -> Array:
    """State-form LogEI for the MSO layer: ``state = (GPState, best)``.

    Module-level pure function ⇒ jit caches key on shapes only; the fitted
    GP flows through as a traced pytree (no per-trial recompilation).
    """
    gp, best = state
    mean, var = predict(gp, xb)
    return log_ei(mean, var, best)


def ucb_acq(state, xb: Array) -> Array:
    """State-form UCB: ``state = (GPState, beta)``."""
    gp, beta = state
    mean, var = predict(gp, xb)
    return mean + beta * jnp.sqrt(var)


def make_logei(gp: GPState, best: float) -> AcqBatched:
    """LogEI closure over a fitted GP (y standardized, maximization scale)."""
    best = jnp.asarray(best, gp.y_train.dtype)

    def acq(xb: Array) -> Array:
        mean, var = predict(gp, xb)
        return log_ei(mean, var, best)

    return acq


def make_ucb(gp: GPState, beta: float = 2.0) -> AcqBatched:
    def acq(xb: Array) -> Array:
        mean, var = predict(gp, xb)
        return ucb(mean, var, beta)

    return acq
