import jax

# BO-side numerics (GP Cholesky, L-BFGS-B trajectories) need f64; model
# tests pass explicit dtypes throughout so this is safe globally.
# NOTE: the 512-device dry-run flag is deliberately NOT set here — tests
# that need a mesh spawn subprocesses (tests/test_distributed.py).
jax.config.update("jax_enable_x64", True)
