"""End-to-end driver: BO (control plane, D-BE inside) tunes the learning
rate + weight decay of an LM training run (data plane).

Reduced scale by default so it runs on CPU in minutes; pass --arch/--steps
/--width to scale up (the same driver shape runs a ~100M model for a few
hundred steps on real hardware: --width 768 --layers 12 --steps 300).

    PYTHONPATH=src python examples/hpo_train.py
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.bo.sampler import GPSampler            # noqa: E402
from repro.bo.space import BoxSpace               # noqa: E402
from repro.configs import get_config              # noqa: E402
from repro.core.mso import MsoOptions             # noqa: E402
from repro.data.synth import DataConfig, synth_batch   # noqa: E402
from repro.models import lm                       # noqa: E402
from repro.train.optim import OptimConfig, init_opt_state  # noqa: E402
from repro.train.step import make_train_step      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--trials", type=int, default=12)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().replace(
        dtype="float32", attn_chunk=32, d_model=args.width,
        n_layers=args.layers, d_ff=2 * args.width)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0)

    def trial(x) -> float:
        log_lr, log_wd = float(x[0]), float(x[1])
        opt_cfg = OptimConfig(lr=10.0 ** log_lr,
                              weight_decay=10.0 ** log_wd,
                              warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg))
        loss = 20.0
        for i in range(args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in synth_batch(cfg, dcfg, i).items()}
            params, opt_state, m = step(params, opt_state, batch)
            loss = float(m["loss"])
            if not np.isfinite(loss):
                return 20.0
        return loss

    space = BoxSpace(np.array([-5.0, -4.0]), np.array([-1.0, -0.5]))
    sampler = GPSampler(space, strategy="dbe", seed=0, n_startup_trials=5,
                        n_restarts=6,
                        mso_options=MsoOptions(maxiter=100, pgtol=1e-2))
    for i in range(args.trials):
        t = sampler.ask()
        y = trial(t.x)
        sampler.tell(t.trial_id, y)
        print(f"trial {t.trial_id}: log_lr={t.x[0]:+.2f} "
              f"log_wd={t.x[1]:+.2f} -> final loss {y:.4f}", flush=True)
    best = sampler.best()
    print(f"\nbest: lr=10^{best.x[0]:.2f} wd=10^{best.x[1]:.2f} "
          f"loss={best.y:.4f}")


if __name__ == "__main__":
    main()
