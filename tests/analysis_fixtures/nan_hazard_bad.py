"""Fixture: non-finite literals and unmasked division inside a
``while_loop`` carry — must trip ``nan-hazard``."""
import jax.numpy as jnp
from jax import lax


def normalize_loop(x):
    def cond(carry):
        i, v = carry
        return i < 8

    def body(carry):
        i, v = carry
        # BAD: unguarded division — a zero-sum (idle/padded) row turns
        # the whole carry into NaN
        scaled = v / v.sum()
        # BAD: raw inf written into the carry, no mask in sight
        ceiling = jnp.full_like(v, jnp.inf)
        return i + 1, jnp.minimum(scaled, ceiling)

    return lax.while_loop(cond, body, (0, x))
